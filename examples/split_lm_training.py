"""Example 4 — the paper's technique applied beyond GANs (§7.3): train
an assigned LM with heterogeneous U-shaped split learning + clustered
KLD federation. Two device profiles (weak/strong) hold different head/
tail depths; the trunk is shared on the server.

    PYTHONPATH=src python examples/split_lm_training.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.split_transformer import (default_groups, federate_split_lm,
                                          init_split_lm,
                                          make_split_train_step)
from repro.data.tokens import lm_batches


def main():
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"), n_layers=6)
    groups = default_groups(cfg, n_weak=2, n_strong=2)
    params = init_split_lm(jax.random.PRNGKey(0), cfg, groups)
    step, opt_init = make_split_train_step(cfg, groups, lr=3e-4)
    opt = opt_init(params)
    step = jax.jit(step)

    gens = {g.name: lm_batches(cfg.vocab, g.n_clients * 2, 32,
                               seed=hash(g.name) % 1000) for g in groups}
    print(f"population: " + ", ".join(
        f"{g.name}(K={g.n_clients}, head={g.cut_head}, tail={g.cut_tail})"
        for g in groups))
    for it in range(12):
        batch = {"tokens": {}, "labels": {}}
        for g in groups:
            toks, labs = next(gens[g.name])
            batch["tokens"][g.name] = jnp.asarray(
                toks.reshape(g.n_clients, 2, 32))
            batch["labels"][g.name] = jnp.asarray(
                labs.reshape(g.n_clients, 2, 32))
        params, opt, m = step(params, opt, batch)
        if it % 3 == 0:
            print(f"iter {it}: loss={float(m['loss']):.4f}")
        if it == 7:  # a federation round (uniform weights, 2 clusters)
            weights = np.full(4, 0.5)
            labels = np.array([0, 0, 1, 1])
            params = federate_split_lm(params, groups, weights, labels)
            print("federated client segments (2 clusters)")
    print(f"final loss: {float(m['loss']):.4f}")


if __name__ == "__main__":
    main()
