"""Example 2 — reproduce the paper's headline comparison (Tables 8-10
analogue): HuSCF-GAN vs FedGAN vs MD-GAN on a two-domain non-IID
population, reporting classifier metrics, dataset scores and the
analytic latency model side by side.

    PYTHONPATH=src python examples/multi_domain_comparison.py [--epochs 6]
"""
import argparse
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)  # for benchmarks.*

import numpy as np

from repro.baselines import FedGANTrainer, MDGANTrainer, BaselineConfig
from repro.core import (HuSCFConfig, HuSCFTrainer, PAPER_DEVICES,
                        fedgan_iteration_latency, mdgan_iteration_latency)
from repro.data import build_scenario
from benchmarks.quality_scenarios import evaluate_trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--clients", type=int, default=8)
    args = ap.parse_args()

    clients = build_scenario("2dom_noniid", num_clients=args.clients,
                             base_size=128, seed=0)
    devices = [PAPER_DEVICES[i % 7] for i in range(args.clients)]

    trainers = {
        "HuSCF-GAN": HuSCFTrainer(clients, devices,
                                  config=HuSCFConfig(batch=16,
                                                     federate_every=2,
                                                     seed=0)),
        "FedGAN": FedGANTrainer(clients, BaselineConfig(batch=16,
                                                        federate_every=2,
                                                        seed=0)),
        "MD-GAN": MDGANTrainer(clients, BaselineConfig(batch=16,
                                                       federate_every=2,
                                                       seed=0)),
    }
    latency = {
        "HuSCF-GAN": trainers["HuSCF-GAN"].ga_latency,
        "FedGAN": fedgan_iteration_latency(devices, 16),
        "MD-GAN": mdgan_iteration_latency(devices, batch=16),
    }
    print(f"{'algo':12s} {'dom':9s} {'acc':>6s} {'f1':>6s} {'score':>6s} "
          f"{'fid':>8s} {'latency-model':>14s}")
    for name, tr in trainers.items():
        for _ in range(args.epochs):
            tr.train_epoch()
        res = evaluate_trainer(tr, ["gratings", "blobs"])
        for dom, m in res.items():
            print(f"{name:12s} {dom:9s} {m['accuracy']*100:5.1f}% "
                  f"{m['f1']*100:5.1f}% {m['score']:6.2f} {m['fid']:8.1f} "
                  f"{latency[name]:12.1f}s")


if __name__ == "__main__":
    main()
