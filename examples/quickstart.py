"""Quickstart: HuSCF-GAN end-to-end in ~2 minutes on CPU.

Trains the paper's split-federated cGAN on a small two-domain non-IID
population, runs a clustered federation round, and evaluates generation
quality with the paper's metrics.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.core import HuSCFConfig, HuSCFTrainer, PAPER_DEVICES
from repro.data import build_scenario, make_class_balanced
from repro.metrics import dataset_score, evaluate
from repro.models.classifier import predict, predict_proba, train_classifier


def main():
    # 1. a heterogeneous population: 6 clients, 2 domains, non-IID
    clients = build_scenario("2dom_noniid", num_clients=6, base_size=96,
                             seed=0)
    devices = [PAPER_DEVICES[i % 7] for i in range(6)]

    # 2. the five-stage HuSCF pipeline (GA cuts -> split training ->
    #    clustering -> KLD federation)
    tr = HuSCFTrainer(clients, devices,
                      config=HuSCFConfig(batch=16, federate_every=2, seed=0))
    print(f"GA-selected cuts give latency-model {tr.ga_latency:.2f} s/iter "
          f"across {len(tr.groups)} device-profile groups")
    for epoch in range(4):
        m = tr.train_epoch()
        print(f"epoch {epoch + 1}: loss_d={m['loss_d']:.3f} "
              f"loss_g={m['loss_g']:.3f}")
    diag = tr.federate()
    print(f"clustered federation: k={diag['k']} "
          f"silhouette={diag['silhouette']:.3f}")

    # 3. evaluate: classifier trained purely on generated data
    labels = np.arange(300) % 10
    gen_imgs, gen_labs = tr.generate(8, labels)
    clf = train_classifier(jax.random.PRNGKey(1), gen_imgs, gen_labs,
                           epochs=3)
    test_i, test_l = make_class_balanced("gratings", 20, seed=9)
    rep = evaluate(test_l, predict(clf, test_i))
    score = dataset_score(predict_proba(clf, gen_imgs))
    print(f"classifier-on-generated: {rep}")
    print(f"dataset score: {score:.2f}")


if __name__ == "__main__":
    main()
