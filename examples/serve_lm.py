"""Example 3 — serve a small assigned-architecture LM with batched
requests: prefill a batch of prompts, then decode continuations with a
bounded KV/recurrent cache. Exercises the same prefill/decode_step pair
the decode_32k / long_500k dry-run shapes lower.

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data.tokens import zipf_tokens
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = zipf_tokens(rng, args.batch * args.prompt_len, cfg.vocab
                          ).reshape(args.batch, args.prompt_len)
    prompts = jnp.asarray(prompts)

    prefill = jax.jit(lambda p, t: T.prefill(cfg, p, t,
                                             margin=args.gen + 16))
    decode = jax.jit(lambda p, t, c: T.decode_step(cfg, p, t, c))

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s; "
          f"cache entries: "
          f"{len(jax.tree_util.tree_leaves(cache))} tensors, "
          f"{sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(cache))/2**20:.1f} MiB")

    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    generated = [np.asarray(cur)]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cur, cache)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        generated.append(np.asarray(cur))
    jax.block_until_ready(logits)
    dt = time.time() - t0
    print(f"decoded {args.gen} tokens x {args.batch} seqs in {dt:.2f}s "
          f"({args.batch*(args.gen-1)/max(dt,1e-9):.1f} tok/s on CPU)")
    print("continuations:", np.stack(generated, 1)[:, :10].tolist())


if __name__ == "__main__":
    main()
