"""Shared fixtures. NOTE: no XLA_FLAGS device forcing here — smoke tests
and benches must see the single real CPU device (the 512-device override
is exclusive to repro/launch/dryrun.py)."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
