"""Shared fixtures. NOTE: no XLA_FLAGS device forcing here — smoke tests
and benches must see the single real CPU device (the 512-device override
is exclusive to repro/launch/dryrun.py).

Multi-device pattern (the ``multihost`` fixture)
------------------------------------------------
Sharded code paths (shard_map federation rounds, mesh-keyed plans) need
N > 1 devices, but ``--xla_force_host_platform_device_count`` is read
exactly once at backend init — it cannot be applied in this process
after jax has been imported (and every test module imports jax). So
sharded tests are written as plain, importable, argument-repr-able
check functions (``_check_*``) plus a thin pytest wrapper that hands
them to ``multihost``:

* On the ordinary 1-device suite, ``multihost`` re-runs the check in a
  spawned subprocess whose environment (built once per session by the
  session-scoped ``_multihost_env`` guard) forces 8 host CPU devices
  *before* jax import. A failing assert fails the subprocess, which
  fails the wrapping test with the child's output attached.
* When the current process itself already has >= 8 devices (the second
  pytest invocation in scripts/ci_smoke.sh runs with the flag set),
  the check runs inline — same assertions, no subprocess tax.

Checks requiring a specific mesh size pick 1/2/4/8 devices out of the
forced 8 via repro.launch.mesh.make_federation_mesh.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

FORCED_DEVICES = 8
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def _multihost_env():
    """Session-scoped env guard: the subprocess environment forcing
    FORCED_DEVICES CPU devices (flag replaced, platform pinned to cpu
    — see launch.mesh.forced_device_env) with src/tests on PYTHONPATH,
    computed once."""
    from repro.launch.mesh import forced_device_env
    return forced_device_env(
        FORCED_DEVICES, [os.path.join(_ROOT, "src"),
                         os.path.join(_ROOT, "tests")])


class _MultiHost:
    def __init__(self, env, inline):
        self._env = env
        self.inline = inline

    def __call__(self, module: str, func: str, *args, timeout: int = 900):
        """Run ``module.func(*args)`` under >= FORCED_DEVICES devices.

        ``args`` must round-trip through repr (ints/floats/strs/tuples)
        so the call can be serialized onto a subprocess command line.
        """
        if self.inline:
            import importlib
            getattr(importlib.import_module(module), func)(*args)
            return
        code = f"import {module} as _m; _m.{func}(*{args!r})"
        proc = subprocess.run([sys.executable, "-c", code], env=self._env,
                              cwd=_ROOT, capture_output=True, text=True,
                              timeout=timeout)
        if proc.returncode != 0:
            pytest.fail(
                f"multihost subprocess {module}.{func}{args!r} failed "
                f"(rc={proc.returncode})\n--- stdout ---\n{proc.stdout}"
                f"\n--- stderr ---\n{proc.stderr}", pytrace=False)


@pytest.fixture(scope="session")
def multihost(_multihost_env):
    return _MultiHost(_multihost_env,
                      inline=jax.device_count() >= FORCED_DEVICES)
