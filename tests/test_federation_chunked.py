"""Chunk-streamed clustered aggregation vs the dense fused path and
the legacy numpy-loop oracle (DESIGN.md §Chunk-streamed aggregation).

The chunked path never materializes the dense ``theta [K, D]`` buffer:
a ``lax.scan`` over fixed-size client chunks accumulates per-segment
weighted partial sums and weight masses, and one normalize at the end
divides them out. Summation is therefore *re-associated* relative to
the dense single-matmul round, so equivalence is tolerance-bounded
(f32 accumulator, observed max-abs ~1e-7 on GAN-sized layers), not
bit-exact — except where a case is engineered to take the identical
compute path, which is asserted byte-identical.

Matrix covered here:
  * chunk sizes 1, small, = K, > K and non-divisible tails, with and
    without the Pallas ``clustered_agg`` kernel, host and device entry
    points;
  * hypothesis property twin over arbitrary (n_clients, chunk_size)
    when hypothesis is installed (bare env: the deterministic sweep
    above is the same assertion on a pinned grid);
  * cohort rounds: full-participation mask is byte-identical to no
    mask, device-dense vs chunked agree at the paper's beta=150 (both
    f32 — the host f64 oracle is only comparable at moderate beta, see
    the f32-underflow note in DESIGN.md), non-members come back
    bit-identical to their pre-round params;
  * a compiled trainer round with ``agg_chunk`` + ``cohort_size`` runs
    under ``jax.transfer_guard('disallow_explicit')`` — streaming adds
    zero host<->device syncs;
  * plan-cache keying on (chunk_size, cohort_size);
  * multihost twin: the chunked scan composes with the client-axis
    ``shard_map`` at 2/4/8 forced CPU devices, and a group size not
    divisible by the mesh falls back (``_chunk_axes is None``) to the
    unsharded stream byte-identically.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kld as kldm
from repro.core.federation import (federate_client_params,
                                   federate_client_params_device,
                                   fedavg_uniform, get_federation_plan)
from repro.core.registry import ClientRegistry
from test_federation_fused import (N_LAYERS, assert_trees_close,
                                   build_population)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # bare env: deterministic grid only
    given = None

MODULE = "test_federation_chunked"
K = 9                                     # module population size
N_CLUSTERS = 3


@pytest.fixture(scope="module")
def population():
    groups, params = build_population(n_clients=K, n_profiles=3)
    rng = np.random.default_rng(11)
    weights = rng.random(K)
    labels = np.arange(K) % N_CLUSTERS
    return groups, params, weights, labels


@pytest.fixture(scope="module")
def dense_and_legacy(population):
    groups, params, weights, labels = population
    legacy = federate_client_params(groups, params, weights, labels,
                                    n_layers=N_LAYERS, fused=False)
    dense = federate_client_params(groups, params, weights, labels,
                                   n_layers=N_LAYERS)
    return legacy, dense


# --------------------------------------------------------------------------
# chunked == dense fused == legacy oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [1, 2, 4, K, K + 5])
def test_chunked_matches_dense_and_legacy(population, dense_and_legacy,
                                          chunk):
    """Every chunk size — including 1 (pure streaming), a non-divisible
    tail (4 over per-group counts of 3), = K and > K (single padded
    chunk) — reproduces both oracles to f32-reassociation tolerance."""
    groups, params, weights, labels = population
    legacy, dense = dense_and_legacy
    got = federate_client_params(groups, params, weights, labels,
                                 n_layers=N_LAYERS, chunk_size=chunk)
    assert_trees_close(got, dense, atol=1e-5)
    assert_trees_close(got, legacy, atol=1e-5)


def test_chunked_kernel_matches_dense(population, dense_and_legacy):
    """The Pallas clustered_agg kernel per chunk agrees with the jnp
    matmul chunk body and with the dense round."""
    groups, params, weights, labels = population
    _, dense = dense_and_legacy
    got = federate_client_params(groups, params, weights, labels,
                                 n_layers=N_LAYERS, chunk_size=3,
                                 use_kernel=True)
    assert_trees_close(got, dense, atol=1e-5)


def test_chunked_device_entry_point(population, dense_and_legacy):
    """federate_client_params_device(chunk_size=) — device weights and
    labels in, no host numpy — matches the dense device round."""
    groups, params, weights, labels = population
    w = jnp.asarray(weights, jnp.float32)
    l = jnp.asarray(labels, jnp.int32)
    dense = federate_client_params_device(groups, params, w, l, N_CLUSTERS,
                                          n_layers=N_LAYERS)
    got = federate_client_params_device(groups, params, w, l, N_CLUSTERS,
                                        n_layers=N_LAYERS, chunk_size=2)
    assert_trees_close(got, dense, atol=1e-5)


def test_chunked_zero_weight_cluster_fallback(population):
    """A cluster whose weights all vanish goes uniform over its
    (participating) members — the same fallback, chunked and dense."""
    groups, params, _, labels = population
    weights = np.random.default_rng(5).random(K)
    weights[labels == 1] = 0.0
    dense = federate_client_params(groups, params, weights, labels,
                                   n_layers=N_LAYERS)
    got = federate_client_params(groups, params, weights, labels,
                                 n_layers=N_LAYERS, chunk_size=2)
    assert_trees_close(got, dense, atol=1e-5)


def test_fedavg_rides_the_chunked_plan(population):
    """Degenerate FedAvg (one cluster, size weights) streams through
    the same scan."""
    groups, params, _, _ = population
    sizes = np.random.default_rng(6).integers(10, 100, K)
    want = fedavg_uniform(groups, params, sizes, n_layers=N_LAYERS)
    got = fedavg_uniform(groups, params, sizes, n_layers=N_LAYERS,
                         chunk_size=4)
    assert_trees_close(got, want, atol=1e-5)


def test_chunked_requires_chunked_plan(population):
    groups, params, weights, labels = population
    tmpl = {g.name: params[g.name]["G"] for g in groups}
    plan = get_federation_plan(groups, "G", 5, tmpl)     # no chunk_size
    with pytest.raises(ValueError, match="chunk_size"):
        plan.aggregate_chunked(tmpl, jnp.asarray(weights, jnp.float32),
                               jnp.asarray(labels, jnp.int32), N_CLUSTERS)


def test_buffer_bytes_are_population_independent(population):
    """The acceptance claim in O() form: the dense buffer grows with
    the client count, the chunk working set doesn't."""
    groups, params, _, _ = population
    tmpl = {g.name: params[g.name]["G"] for g in groups}
    plan = get_federation_plan(groups, "G", 5, tmpl, chunk_size=2)
    big_groups, big_params = build_population(n_clients=3 * K, n_profiles=3)
    big_tmpl = {g.name: big_params[g.name]["G"] for g in big_groups}
    big = get_federation_plan(big_groups, "G", 5, big_tmpl, chunk_size=2)
    assert big.dense_buffer_bytes() == 3 * plan.dense_buffer_bytes()
    assert (big.chunked_buffer_bytes(N_CLUSTERS)
            == plan.chunked_buffer_bytes(N_CLUSTERS))
    # the workset (dominated by acc [S, D]) wins once clients outnumber
    # segments — at 27 clients vs S=16 it already does; at 9 it needn't
    assert big.chunked_buffer_bytes(N_CLUSTERS) < big.dense_buffer_bytes()


# --------------------------------------------------------------------------
# hypothesis property twin (skipped in the bare env)
# --------------------------------------------------------------------------

def _assert_chunked_equals_dense(seed, n_clients, chunk):
    groups, params = build_population(n_clients, n_profiles=3, seed=seed)
    rng = np.random.default_rng(seed + 1)
    weights = rng.random(n_clients)
    labels = rng.integers(0, N_CLUSTERS, n_clients)
    dense = federate_client_params(groups, params, weights, labels,
                                   n_layers={"G": 5})
    got = federate_client_params(groups, params, weights, labels,
                                 n_layers={"G": 5}, chunk_size=chunk)
    assert_trees_close(got, dense, atol=1e-5)


if given is not None:
    @given(seed=st.integers(0, 2 ** 31 - 1), n_clients=st.integers(3, 12),
           chunk=st.integers(1, 16))
    @settings(max_examples=10, deadline=None)
    def test_chunked_equals_dense_property(seed, n_clients, chunk):
        """Arbitrary (n_clients, chunk_size) — chunk > K, chunk = 1 and
        non-divisible tails all arise from the search space."""
        _assert_chunked_equals_dense(seed, n_clients, chunk)
else:
    @pytest.mark.skip(reason="property tests need hypothesis (bare env); "
                             "the deterministic sweep above pins the grid")
    def test_chunked_equals_dense_property():
        pass


# --------------------------------------------------------------------------
# cohort rounds
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cohort_case(population):
    groups, params, _, labels = population
    reg = ClientRegistry(sizes=np.random.default_rng(8).integers(20, 200, K))
    ids = reg.sample_cohort(jax.random.PRNGKey(0), 5)
    mask = reg.cohort_mask(ids)
    klds = np.random.default_rng(9).random(K) * 2.0
    return groups, params, labels, reg, np.asarray(mask), klds


def test_full_cohort_mask_is_identity(population):
    """An all-ones mask takes the identical compute path as no mask
    (participation = 1 everywhere) — byte-identical output."""
    groups, params, weights, labels = population
    base = federate_client_params(groups, params, weights, labels,
                                  n_layers=N_LAYERS, chunk_size=3)
    got = federate_client_params(groups, params, weights, labels,
                                 n_layers=N_LAYERS, chunk_size=3,
                                 cohort_mask=np.ones(K, bool))
    assert_trees_close(got, base, atol=0)


def test_cohort_chunked_matches_dense_device_at_paper_beta(cohort_case):
    """beta=150 cohort weights (log-space, f32) through the chunked
    stream vs the dense device round — the two f32 paths, which share
    the participation-aware uniform fallback, agree tightly even where
    the weights graze the f32 underflow cliff."""
    groups, params, labels, reg, mask, klds = cohort_case
    w = kldm.cohort_federation_weights_jax(
        jnp.asarray(klds, jnp.float32), jnp.asarray(reg.sizes, jnp.float32),
        jnp.asarray(labels, jnp.int32), jnp.asarray(mask), N_CLUSTERS,
        beta=150.0)
    l = jnp.asarray(labels, jnp.int32)
    m = jnp.asarray(mask)
    dense = federate_client_params_device(groups, params, w, l, N_CLUSTERS,
                                          n_layers=N_LAYERS, cohort_mask=m)
    got = federate_client_params_device(groups, params, w, l, N_CLUSTERS,
                                        n_layers=N_LAYERS, chunk_size=2,
                                        cohort_mask=m, cohort_size=5)
    assert_trees_close(got, dense, atol=1e-5)


def test_cohort_chunked_matches_host_oracle_moderate_beta(cohort_case):
    """Host f64 oracle (cohort_federation_weights + per-segment
    renormalize) vs the chunked stream at beta=5 — moderate beta keeps
    every cohort weight representable in f32, where the two paths are
    the same formula (at beta=150 the host f64 renormalize can recover
    weights that underflow to 0 in f32; see DESIGN.md)."""
    groups, params, labels, reg, mask, klds = cohort_case
    w = kldm.cohort_federation_weights(klds, reg.sizes, labels, mask,
                                       beta=5.0)
    host = federate_client_params(groups, params, w, labels,
                                  n_layers=N_LAYERS, cohort_mask=mask)
    got = federate_client_params(groups, params, w, labels,
                                 n_layers=N_LAYERS, chunk_size=3,
                                 cohort_mask=mask)
    assert_trees_close(got, host, atol=1e-5)


def test_cohort_non_members_bit_identical(cohort_case):
    """Non-members neither contribute nor receive: their returned
    params are the exact input arrays, all paths."""
    groups, params, labels, reg, mask, klds = cohort_case
    w = kldm.cohort_federation_weights(klds, reg.sizes, labels, mask,
                                       beta=5.0)
    wj = jnp.asarray(w, jnp.float32)
    lj = jnp.asarray(labels, jnp.int32)
    outs = [
        federate_client_params(groups, params, w, labels, n_layers=N_LAYERS,
                               cohort_mask=mask),
        federate_client_params(groups, params, w, labels, n_layers=N_LAYERS,
                               chunk_size=3, cohort_mask=mask),
        federate_client_params_device(groups, params, wj, lj, N_CLUSTERS,
                                      n_layers=N_LAYERS,
                                      cohort_mask=jnp.asarray(mask)),
        federate_client_params_device(groups, params, wj, lj, N_CLUSTERS,
                                      n_layers=N_LAYERS, chunk_size=2,
                                      cohort_mask=jnp.asarray(mask),
                                      cohort_size=int(mask.sum())),
    ]
    touched = 0
    for g in groups:
        for pos, cid in enumerate(g.client_ids):
            if mask[cid]:
                continue
            touched += 1
            for net in ("G", "D"):
                for l, tree in params[g.name][net].items():
                    want = jax.tree_util.tree_leaves(tree)
                    for out in outs:
                        got = jax.tree_util.tree_leaves(out[g.name][net][l])
                        for a, b in zip(got, want):
                            assert np.array_equal(np.asarray(a[pos]),
                                                  np.asarray(b[pos]))
    assert touched == K - int(mask.sum()) > 0


# --------------------------------------------------------------------------
# plan cache keys on (chunk_size, cohort_size)
# --------------------------------------------------------------------------

def test_plan_cache_keys_on_chunk_and_cohort(population):
    groups, params, _, _ = population
    tmpl = {g.name: params[g.name]["G"] for g in groups}
    cache = {}
    base = get_federation_plan(groups, "G", 5, tmpl, plan_cache=cache)
    c2 = get_federation_plan(groups, "G", 5, tmpl, plan_cache=cache,
                             chunk_size=2)
    c4 = get_federation_plan(groups, "G", 5, tmpl, plan_cache=cache,
                             chunk_size=4)
    c2s = get_federation_plan(groups, "G", 5, tmpl, plan_cache=cache,
                              chunk_size=2, cohort_size=5)
    assert len(cache) == 4
    assert len({id(base), id(c2), id(c4), id(c2s)}) == 4
    # re-requesting each key hits the cached plan
    assert get_federation_plan(groups, "G", 5, tmpl, plan_cache=cache,
                               chunk_size=2) is c2
    assert get_federation_plan(groups, "G", 5, tmpl, plan_cache=cache,
                               chunk_size=2, cohort_size=5) is c2s
    assert len(cache) == 4
    assert base.chunk_size is None and c2.chunk_size == 2
    assert c2s.cohort_size == 5


# --------------------------------------------------------------------------
# trainer round: cohort + chunked, zero host<->device syncs
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cohort_trainer():
    from repro.core import HuSCFConfig, HuSCFTrainer, PAPER_DEVICES
    from repro.core.latency import Cut
    from repro.data import build_scenario
    from test_cluster_fused import _ema_blobs
    clients = build_scenario("2dom_iid", num_clients=8, base_size=16, seed=0)
    devices = [PAPER_DEVICES[i % 2] for i in range(8)]
    cuts = [Cut(1, 3, 1, 3) if i % 2 == 0 else Cut(2, 4, 2, 4)
            for i in range(8)]
    cfg = HuSCFConfig(batch=2, steps_per_epoch=2, federate_every=10 ** 6,
                      seed=0, warmup_fed_rounds=0, fused_cluster=True,
                      cohort_size=5, agg_chunk=3)
    tr = HuSCFTrainer(clients, devices, cuts=cuts, config=cfg)
    tr.train_steps(1)
    tr._mid_ema = jnp.asarray(_ema_blobs(8))
    before = jax.tree_util.tree_map(
        np.asarray, {net: tr.state[net]["client"] for net in ("G", "D")})
    diag = tr.federate()                   # compiles the cohort round
    return tr, before, diag


def test_trainer_cohort_chunked_round(cohort_trainer):
    """The wired round clusters, reports its sampled cohort, and leaves
    every non-member's client params bit-identical."""
    tr, before, diag = cohort_trainer
    assert diag["mode"] == "clustered"
    cohort = np.asarray(diag["cohort"])
    assert cohort.shape == (5,) and len(np.unique(cohort)) == 5
    member = np.zeros(8, bool)
    member[cohort] = True
    for g in tr.groups:
        for pos, cid in enumerate(g.client_ids):
            if member[cid]:
                continue
            for net in ("G", "D"):
                got = jax.tree_util.tree_leaves(
                    tr.state[net]["client"][g.name])
                want = jax.tree_util.tree_leaves(before[net][g.name])
                for a, b in zip(got, want):
                    np.testing.assert_array_equal(np.asarray(a[pos]), b[pos])


def test_trainer_cohort_chunked_zero_host_transfers(cohort_trainer):
    """The acceptance property: with the cohort+chunked round compiled,
    sampling, clustering, weighting and the chunk-streamed aggregation
    all run under jax.transfer_guard('disallow_explicit')."""
    tr, _, _ = cohort_trainer
    tr.train_steps(1)
    with jax.transfer_guard("disallow_explicit"):
        diag = tr.federate()
    assert diag["mode"] == "clustered"


# --------------------------------------------------------------------------
# multihost twin: chunk stream x client-axis shard_map
# --------------------------------------------------------------------------

def _check_chunked_sharded():
    """16 clients / 4 profile groups (4 per group): meshes of 2/4
    divide every group, so the chunk stream shards; results match the
    unsharded chunked round and the dense fused oracle. An 8-device
    mesh does not divide the per-group count of 4, so the plan falls
    back (``_chunk_axes is None``) to the unsharded stream
    byte-identically."""
    import jax
    import numpy as np
    from repro.core.federation import (federate_client_params,
                                       get_federation_plan)
    from repro.launch.mesh import make_federation_mesh
    from test_federation_fused import (N_LAYERS, assert_trees_close,
                                       build_population)
    assert jax.device_count() >= 8
    groups, params = build_population(n_clients=16, n_profiles=4, seed=2)
    rng = np.random.default_rng(3)
    weights, labels = rng.random(16), np.arange(16) % 3
    tmpl = {g.name: params[g.name]["G"] for g in groups}

    def fed(**kw):
        return federate_client_params(groups, params, weights, labels,
                                      n_layers=N_LAYERS, chunk_size=2, **kw)

    dense = federate_client_params(groups, params, weights, labels,
                                   n_layers=N_LAYERS)
    unsharded = fed()
    assert_trees_close(unsharded, dense, atol=1e-5)
    for nd in (2, 4):
        mesh = make_federation_mesh(nd)
        plan = get_federation_plan(groups, "G", 5, tmpl, mesh=mesh,
                                   chunk_size=2)
        assert plan._chunk_axes == "data", f"{nd}-device mesh must shard"
        assert_trees_close(fed(mesh=mesh), unsharded, atol=1e-5)
        assert_trees_close(fed(mesh=mesh), dense, atol=1e-5)
    # kernel body under the sharded stream
    assert_trees_close(fed(mesh=make_federation_mesh(4), use_kernel=True),
                       dense, atol=1e-5)
    # 8 devices don't divide the per-group count of 4 -> unsharded
    # fallback, byte-identical to the plain chunk stream
    mesh8 = make_federation_mesh(8)
    plan8 = get_federation_plan(groups, "G", 5, tmpl, mesh=mesh8,
                                chunk_size=2)
    assert plan8._chunk_axes is None
    got8 = fed(mesh=mesh8)
    gl = jax.tree_util.tree_leaves(got8)
    ul = jax.tree_util.tree_leaves(unsharded)
    for g, u in zip(gl, ul):
        assert np.array_equal(np.asarray(g), np.asarray(u))


def _check_chunked_cohort_sharded():
    """Cohort round through the sharded chunk stream: per-group cids
    shard with the clients; non-members stay bit-identical."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import kld as kldm
    from repro.core.federation import federate_client_params_device
    from repro.core.registry import ClientRegistry
    from repro.launch.mesh import make_federation_mesh
    from test_federation_fused import (N_LAYERS, assert_trees_close,
                                       build_population)
    assert jax.device_count() >= 8
    groups, params = build_population(n_clients=16, n_profiles=4, seed=4)
    labels = np.arange(16) % 3
    reg = ClientRegistry(sizes=np.random.default_rng(5).integers(20, 200, 16))
    mask = np.asarray(reg.cohort_mask(
        reg.sample_cohort(jax.random.PRNGKey(1), 10)))
    w = kldm.cohort_federation_weights(
        np.random.default_rng(6).random(16), reg.sizes, labels, mask,
        beta=5.0)

    def fed(**kw):
        return federate_client_params_device(
            groups, params, jnp.asarray(w, jnp.float32),
            jnp.asarray(labels, jnp.int32), 3, n_layers=N_LAYERS,
            chunk_size=2, cohort_mask=jnp.asarray(mask), cohort_size=10,
            **kw)

    unsharded = fed()
    sharded = fed(mesh=make_federation_mesh(4))
    assert_trees_close(sharded, unsharded, atol=1e-5)
    for g in groups:
        for pos, cid in enumerate(g.client_ids):
            if mask[cid]:
                continue
            for l, tree in params[g.name]["G"].items():
                want = jax.tree_util.tree_leaves(tree)
                got = jax.tree_util.tree_leaves(sharded[g.name]["G"][l])
                for a, b in zip(got, want):
                    assert np.array_equal(np.asarray(a[pos]),
                                          np.asarray(b[pos]))


# --------------------------------------------------------------------------
# bucket-padded program sharing (satellite: compile-cache stability)
# --------------------------------------------------------------------------

def test_chunked_program_shared_across_bucket_sizes():
    """Two populations whose per-group sizes differ but land in the
    same power-of-two buckets (3 -> pad 4 vs true 4) execute the SAME
    compiled chunked round — the layout is keyed on buckets, actual
    counts arrive as traced scalars."""
    from repro.core.federation import _chunked_fn_cache_stats
    g9, p9 = build_population(n_clients=9, n_profiles=3, seed=1)
    g12, p12 = build_population(n_clients=12, n_profiles=3, seed=2)
    assert [g.name for g in g9] == [g.name for g in g12]
    rng = np.random.default_rng(3)

    def fed(groups, params, k):
        return federate_client_params(groups, params, rng.random(k),
                                      np.arange(k) % N_CLUSTERS,
                                      n_layers=N_LAYERS, chunk_size=2)
    a = fed(g9, p9, 9)
    after_first = _chunked_fn_cache_stats()
    b = fed(g12, p12, 12)
    after_second = _chunked_fn_cache_stats()
    assert after_second == after_first        # no new program, no retrace
    # and the padded round still computes the right thing
    dense = federate_client_params(g12, p12, rng.random(12),
                                   np.arange(12) % N_CLUSTERS,
                                   n_layers=N_LAYERS)
    assert set(b) == set(dense)
    del a


def test_trainer_chunked_cache_stable_across_churn():
    """The regression the bucket padding exists for: a churn rebuild
    flushes the trainer's FederationPlans, but as long as the regrouped
    sizes stay within their buckets the rebuilt plan replays the SAME
    compiled chunked round — no recompile per joined/left client."""
    from repro.core.federation import _chunked_fn_cache_stats
    from repro.core.genetic import GAConfig
    from repro.core.huscf import HuSCFConfig, HuSCFTrainer
    from repro.core.latency import PAPER_DEVICES
    from test_recut import mk_clients
    cfg = HuSCFConfig(batch=8, federate_every=1, seed=0, steps_per_epoch=1,
                      warmup_fed_rounds=0, agg_chunk=2)
    # two profiles -> a 256-point gene space the 128-individual GA
    # certainly solves identically before and after churn (test_recut's
    # tie-stability argument), so only group SIZES change.
    ga = GAConfig(population_size=128, generations=12, seed=0,
                  early_stop_patience=6)
    clients = mk_clients(6)
    devices = [PAPER_DEVICES[i % 2] for i in range(6)]
    tr = HuSCFTrainer(clients, devices, config=cfg, ga_config=ga)
    tr.train_steps(1)
    tr.federate()
    cuts_before = [c.as_tuple() for c in tr.cuts]
    sizes_before = sorted(g.size for g in tr.groups)
    stats = _chunked_fn_cache_stats()
    # join one client on an incumbent profile: 3 -> 4 stays in bucket 4
    joiner = mk_clients(1, seed=9, id0=6)[0]
    tr.apply_churn(join=[(joiner, PAPER_DEVICES[0])])
    assert [c.as_tuple() for c in tr.cuts][:6] == cuts_before
    assert sorted(g.size for g in tr.groups) != sizes_before
    tr.train_steps(1)
    tr.federate()
    assert _chunked_fn_cache_stats() == stats


def test_chunked_sharded_multihost(multihost):
    multihost(MODULE, "_check_chunked_sharded")


def test_chunked_cohort_sharded_multihost(multihost):
    multihost(MODULE, "_check_chunked_cohort_sharded")
