"""The vectorized Eq. 3-10 latency model (core/latency_jax) against the
host reference, plus baseline-model sanity checks.

The fused GA's fitness is only as good as this equivalence: tables are
f64-exact values rounded once to f32, so the device result must track
the host f64 model to 1e-6 relative over the *whole* cut-option space
for every device-mix width the trainer produces.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.latency import (Cut, PAPER_DEVICES, PAPER_SERVER,
                                all_cut_options, fedgan_iteration_latency,
                                fedsplitgan_iteration_latency,
                                hflgan_iteration_latency,
                                huscf_iteration_latency,
                                mdgan_iteration_latency,
                                pflgan_iteration_latency)
from repro.core.latency_jax import (build_latency_tables,
                                    huscf_iteration_latency_jax,
                                    population_latency)

OPTIONS = all_cut_options()
REL_TOL = 1e-6


def _mix(n_clients: int):
    return [PAPER_DEVICES[i % len(PAPER_DEVICES)] for i in range(n_clients)]


def _rel_err(a: float, b: float) -> float:
    return abs(a - b) / max(abs(b), 1e-12)


@pytest.mark.parametrize("n_clients", [1, 3, 7])
def test_matches_host_over_all_options(n_clients):
    """Seeded sweep: every same-option assignment plus 50 random
    per-client combinations per mix, all within 1e-6 relative."""
    devices = _mix(n_clients)
    tables = build_latency_tables(devices, PAPER_SERVER, batch=64)
    rng = np.random.default_rng(1234 + n_clients)
    assignments = [np.full(n_clients, o, np.int32)
                   for o in range(len(OPTIONS))]
    assignments += [rng.integers(0, len(OPTIONS), n_clients).astype(np.int32)
                    for _ in range(50)]
    worst = 0.0
    for idx in assignments:
        cuts = [OPTIONS[o] for o in idx]
        host = huscf_iteration_latency(cuts, devices, PAPER_SERVER, 64)
        dev = float(huscf_iteration_latency_jax(tables, jnp.asarray(idx)))
        worst = max(worst, _rel_err(dev, host))
    assert worst < REL_TOL, f"worst rel err {worst:.3e} over {REL_TOL:.0e}"


def test_population_eval_matches_per_individual():
    devices = _mix(5)
    tables = build_latency_tables(devices, PAPER_SERVER, batch=64)
    rng = np.random.default_rng(7)
    pop = jnp.asarray(rng.integers(0, len(OPTIONS), (32, 5)), jnp.int32)
    lat_pop = np.asarray(population_latency(tables, pop))
    for p in range(pop.shape[0]):
        one = float(huscf_iteration_latency_jax(tables, pop[p]))
        assert abs(lat_pop[p] - one) <= 1e-6 * abs(one)


def test_profile_counts_collapse_is_exact():
    """Appendix D taken into the fitness: evaluating the 7 unique
    profiles with a client-count vector must equal evaluating all
    clients expanded (max is idempotent over identical clients; only
    n_active needs multiplicity)."""
    counts_np = np.array([5, 1, 3, 2, 8, 1, 4], np.int64)
    reps = list(PAPER_DEVICES)
    expanded = [d for d, c in zip(reps, counts_np) for _ in range(c)]
    t_reps = build_latency_tables(reps, PAPER_SERVER, batch=64)
    t_full = build_latency_tables(expanded, PAPER_SERVER, batch=64)
    rng = np.random.default_rng(11)
    for _ in range(25):
        gene = rng.integers(0, len(OPTIONS), 7).astype(np.int32)
        idx_full = np.repeat(gene, counts_np)
        collapsed = float(huscf_iteration_latency_jax(
            t_reps, jnp.asarray(gene),
            jnp.asarray(counts_np, jnp.float32)))
        full = float(huscf_iteration_latency_jax(t_full,
                                                 jnp.asarray(idx_full)))
        assert _rel_err(collapsed, full) < REL_TOL


def test_eval_is_transfer_free():
    """The table-driven evaluation must not pull anything to host: it
    is the GA fitness running inside the per-round search dispatch."""
    devices = _mix(4)
    tables = build_latency_tables(devices, PAPER_SERVER, batch=64)
    fn = jax.jit(lambda pop: population_latency(tables, pop))
    pop = jnp.zeros((8, 4), jnp.int32)
    with jax.transfer_guard("disallow_explicit"):
        out = fn(pop)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("model", [
    fedgan_iteration_latency, hflgan_iteration_latency,
    pflgan_iteration_latency,
    lambda d, b: mdgan_iteration_latency(d, batch=b),
    lambda d, b: fedsplitgan_iteration_latency(d, batch=b),
])
def test_baseline_batch_monotone(model):
    """Sanity for every baseline latency model: a bigger batch can
    never be faster (all terms scale with b)."""
    devices = _mix(6)
    prev = 0.0
    for batch in (8, 16, 32, 64, 128):
        lat = model(devices, batch)
        assert lat >= prev
        assert lat > 0
        prev = lat


def test_huscf_batch_monotone_over_options():
    devices = _mix(4)
    for opt in range(0, len(OPTIONS), 5):
        cuts = [OPTIONS[opt]] * 4
        lats = [huscf_iteration_latency(cuts, devices, PAPER_SERVER, b)
                for b in (16, 32, 64)]
        assert lats[0] < lats[1] < lats[2]
