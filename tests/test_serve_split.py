"""The split-serving engine (launch/serve_split.py): the U-shaped
SplitProgram executor serving real requests.

Covers the ISSUE acceptance bar: the engine executes the actual
U-shaped schedule (client-personal heads/tails around the batched
server trunk) for >= 2 heterogeneous profile mixes and matches a
monolithic per-client forward; bucket-padded cohorts reuse one
compiled program per (active cuts, buckets) signature; the analytic
Eq. 7/9 prediction comes from the same program the executor runs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.splitting import bucket_size
from repro.launch.serve_split import (ServeRequest, SplitGanEngine,
                                      SplitLMConfig, build_mix,
                                      init_gan_serving_state, init_split_lm,
                                      lm_reference_logits,
                                      split_lm_decode_logits,
                                      split_lm_generate)
from repro.models import gan

MIXES = ("edge-heavy", "balanced")


def _mk_engine(mix, seed=0):
    groups = build_mix(mix)
    client, server = init_gan_serving_state(jax.random.PRNGKey(seed), groups)
    return SplitGanEngine(groups, client, server), groups


def _mk_requests(groups, n, seed=0):
    rng = np.random.default_rng(seed)
    n_clients = sum(g.size for g in groups)
    return [ServeRequest(int(rng.integers(0, n_clients)),
                         rng.normal(0, 1, gan.Z_DIM).astype(np.float32),
                         int(rng.integers(0, gan.NUM_CLASSES)))
            for _ in range(n)]


def _monolithic_forward(groups, client, server, req):
    """The oracle: assemble THIS client's full generator (its personal
    head/tail rows + the server's middle layers) and run it unsplit."""
    g = next(gg for gg in groups if req.client_id in gg.client_ids)
    row = g.client_ids.index(req.client_id)
    h, t = g.cut.g_h, g.cut.g_t
    params = []
    for l in range(gan.GEN_LAYERS):
        if l < h or l >= t:
            params.append(jax.tree_util.tree_map(
                lambda x: x[row], client[g.name][str(l)]))
        else:
            params.append(server[str(l)])
    z = jnp.asarray(req.z)[None]
    y = jnp.asarray([req.y], jnp.int32)
    img, _ = gan.generator_forward(params, z, y, train=False)
    return np.asarray(img[0])


@pytest.mark.parametrize("mix", MIXES)
def test_engine_matches_monolithic_per_client(mix):
    engine, groups = _mk_engine(mix)
    reqs = _mk_requests(groups, 11, seed=3)
    imgs = engine.serve(reqs)
    assert imgs.shape == (11, 28, 28, 1)
    for i, req in enumerate(reqs):
        want = _monolithic_forward(groups, engine.client_params,
                                   engine.server_params, req)
        np.testing.assert_allclose(imgs[i], want, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("mix", MIXES)
def test_engine_heterogeneous_schedule_is_real(mix):
    """The compiled program actually is U-shaped and heterogeneous:
    multiple distinct cuts, join barriers at each head end."""
    engine, groups = _mk_engine(mix)
    reqs = _mk_requests(groups, 16)
    active, buckets, per = engine.plan(reqs)
    assert len(active) >= 2
    program = engine.program_for(active)
    assert len({program.cut_of(g) for g in active}) >= 2
    joins = [g for s in program.steps for g in s.joins]
    departs = [g for s in program.steps for g in s.departs]
    assert sorted(joins) == sorted(active)
    assert sorted(departs) == sorted(active)
    assert all(bucket_size(len(per[g])) == b
               for g, b in zip(active, buckets))


def test_engine_deterministic_and_program_reuse():
    """Same requests -> bit-identical images; a churned cohort within
    the same buckets reuses the SAME compiled executor (no retrace)."""
    engine, groups = _mk_engine("edge-heavy")
    reqs = _mk_requests(groups, 10, seed=1)
    a = engine.serve(reqs)
    b = engine.serve(reqs)
    assert np.array_equal(a, b)
    n_fns = len(engine._fns)
    traces = {k: f._cache_size() for k, f in engine._fns.items()}
    # a different cohort with the same per-group bucket signature
    reqs2 = _mk_requests(groups, 10, seed=2)
    if engine.plan(reqs2)[:2] == engine.plan(reqs)[:2]:
        engine.serve(reqs2)
        assert len(engine._fns) == n_fns
        assert {k: f._cache_size()
                for k, f in engine._fns.items()} == traces


def test_engine_subset_cohort_drops_absent_cuts():
    """Requests touching one group compile a subprogram without the
    other cuts' join barriers."""
    engine, groups = _mk_engine("balanced")
    g0 = groups[0]
    rng = np.random.default_rng(0)
    reqs = [ServeRequest(g0.client_ids[0],
                         rng.normal(0, 1, gan.Z_DIM).astype(np.float32), 7)]
    active, buckets, _ = engine.plan(reqs)
    assert active == (g0.name,)
    program = engine.program_for(active)
    assert program.group_names == (g0.name,)
    h, t = g0.cut.g_h, g0.cut.g_t
    assert program.server_span() == tuple(range(h, t))
    imgs = engine.serve(reqs)
    want = _monolithic_forward(groups, engine.client_params,
                               engine.server_params, reqs[0])
    np.testing.assert_allclose(imgs[0], want, atol=1e-5, rtol=1e-5)


def test_predict_latency_from_same_program():
    engine, groups = _mk_engine("edge-heavy")
    reqs = _mk_requests(groups, 9)
    padded = engine.predict_latency(reqs, padded=True)
    exact = engine.predict_latency(reqs, padded=False)
    assert padded >= exact > 0.0
    # prediction is pure analysis: no executor compile required
    assert engine.predict_latency(reqs) == padded


def _check_cohort_axes():
    """cohort_axes: power-of-two buckets shard whenever bucket >= data
    axes; ragged/odd bucket mixes fall back to None. (multihost: needs
    a real multi-device mesh.)"""
    from repro.launch.mesh import make_federation_mesh
    from repro.sharding.policy import cohort_axes
    mesh = make_federation_mesh(4)
    assert cohort_axes(mesh, [4, 8, 16]) == "data"
    assert cohort_axes(mesh, [2, 8]) is None      # 2 % 4 != 0
    assert cohort_axes(mesh, [1]) is None
    mesh1 = make_federation_mesh(1)
    assert cohort_axes(mesh1, [4, 8]) is None     # nothing to shard over


def test_cohort_axes_multihost(multihost):
    multihost("test_serve_split", "_check_cohort_axes")


# ---------------------------------------------------------------------------
# LM decode tail
# ---------------------------------------------------------------------------

def test_split_lm_matches_monolithic_reference():
    """U-shaped decode (server trunk on mem_attention/flash_decode,
    KV caches on the scan carry) == monolithic dense forward."""
    cfg = SplitLMConfig(s_max=96)
    params = init_split_lm(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(0)
    S, P = 40, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (3, S)), dtype=jnp.int32)
    eng = np.asarray(split_lm_decode_logits(cfg, params, toks, P))
    want = np.asarray(lm_reference_logits(cfg, params, toks))[:, P - 1:S - 1]
    assert eng.shape == want.shape
    np.testing.assert_allclose(eng, want, atol=2e-4, rtol=2e-4)


def test_split_lm_generate_greedy_consistency():
    """Greedy scan generation replays the teacher-forced logits: token
    t is the argmax of the decode logits when fed its own prefix."""
    cfg = SplitLMConfig(s_max=64)
    params = init_split_lm(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(1)
    P, G = 16, 8
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, P)), dtype=jnp.int32)
    toks = np.asarray(split_lm_generate(cfg, params, prompt, G))
    assert toks.shape == (2, G)
    full = jnp.concatenate([prompt, jnp.asarray(toks)], axis=1)
    logits = np.asarray(split_lm_decode_logits(cfg, params, full, P))
    np.testing.assert_array_equal(toks, np.argmax(logits, -1))
