"""End-to-end behaviour of the paper's system (HuSCF-GAN) plus the
baselines on the synthetic multi-domain benchmark — small-scale
integration of all five stages."""
import jax
import numpy as np
import pytest

from repro.baselines import ALL_BASELINES, BaselineConfig
from repro.core import HuSCFConfig, HuSCFTrainer, PAPER_DEVICES
from repro.core.latency import Cut
from repro.data import build_scenario
from repro.metrics import dataset_score, evaluate


@pytest.fixture(scope="module")
def clients():
    return build_scenario("2dom_iid", num_clients=6, base_size=48, seed=0)


@pytest.fixture(scope="module")
def trained_trainer(clients):
    devices = [PAPER_DEVICES[i % 3] for i in range(6)]
    cuts = [Cut(1, 3, 1, 3) if i % 3 == 0 else Cut(2, 4, 2, 4)
            for i in range(6)]
    tr = HuSCFTrainer(clients, devices, cuts=cuts,
                      config=HuSCFConfig(batch=8, steps_per_epoch=2,
                                         federate_every=1, seed=0))
    for _ in range(3):
        tr.train_epoch()
    return tr


def test_five_stage_pipeline(trained_trainer):
    tr = trained_trainer
    assert tr.fed_round >= 3                  # stage 3+4 ran
    assert np.isfinite(tr.ga_latency)
    m = tr.history[-1]
    assert np.isfinite(m["loss_d"]) and np.isfinite(m["loss_g"])


def test_generation_shapes_and_range(trained_trainer):
    labels = np.arange(30) % 10
    imgs, labs = trained_trainer.generate(4, labels)
    assert imgs.shape == (30, 28, 28, 1)
    assert labs.shape == (30,)
    assert np.abs(imgs).max() <= 1.0 + 1e-5
    assert np.isfinite(imgs).all()


def test_federation_diagnostics(trained_trainer):
    diag = trained_trainer.federate()
    assert diag["mode"] == "clustered"
    assert 1 <= diag["k"] <= 6
    w = diag["weights"]
    for c in np.unique(diag["labels"]):
        # weights are f32: a per-cluster partition can legitimately sum
        # a few ULPs (1 ULP at 1.0 = 1.19e-7) away from exactly 1.0
        np.testing.assert_allclose(w[diag["labels"] == c].sum(), 1.0,
                                   atol=5e-7)


def test_label_kld_variant(trained_trainer):
    diag = trained_trainer.federate(use_label_kld=True)
    assert diag["mode"] == "clustered"


def test_no_raw_data_leaves_clients(clients):
    """Data-sharing constraint: the server-side state must not contain
    any client images/labels — only parameters and activations."""
    devices = [PAPER_DEVICES[0]] * len(clients)
    cuts = [Cut(1, 3, 1, 3)] * len(clients)
    tr = HuSCFTrainer(clients, devices, cuts=cuts,
                      config=HuSCFConfig(batch=4, steps_per_epoch=1, seed=0))
    tr.train_steps(1)
    server_leaves = jax.tree_util.tree_leaves(
        {"G": tr.state["G"]["server"], "D": tr.state["D"]["server"]})
    img = clients[0].images
    for leaf in server_leaves:
        assert np.asarray(leaf).shape != img.shape
    # mid-layer activations shared with the server are batch-averaged
    acts = tr.middle_activations()
    assert acts.shape[0] == len(clients)
    assert acts.ndim == 2  # no per-sample data


@pytest.mark.parametrize("name", sorted(ALL_BASELINES))
def test_baseline_trains_and_generates(name, clients):
    cfg = BaselineConfig(batch=8, steps_per_epoch=1, federate_every=1, seed=0)
    tr = ALL_BASELINES[name](clients, cfg)
    m = tr.train_epoch()
    assert np.isfinite(m["loss_d"]) and np.isfinite(m["loss_g"])
    imgs, labs = tr.generate(4, np.arange(10))
    assert imgs.shape[0] == 10 and np.isfinite(imgs).all()


def test_metrics_pipeline_sane():
    """Classifier metrics + dataset score on ground-truth synthetic data:
    real data must score far better than noise."""
    from repro.data import make_class_balanced
    from repro.models.classifier import train_classifier, predict, predict_proba
    imgs, labs = make_class_balanced("gratings", 40, seed=0)
    test_i, test_l = make_class_balanced("gratings", 15, seed=99)
    params = train_classifier(jax.random.PRNGKey(0), imgs, labs, epochs=5)
    rep = evaluate(test_l, predict(params, test_i))
    assert rep.accuracy > 0.6
    score_real = dataset_score(predict_proba(params, test_i))
    rng = np.random.default_rng(0)
    noise = rng.uniform(-1, 1, test_i.shape).astype(np.float32)
    score_noise = dataset_score(predict_proba(params, noise))
    assert score_real > score_noise
