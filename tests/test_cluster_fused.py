"""Device-resident clustered federation (DESIGN.md §Device-resident
clustering): the three stage-3/4 numerical bugfixes (KLD weight
underflow, singleton-silhouette bias, empty-cluster re-seed), the
jitted cluster+weight chain vs the numpy oracle, and the fused
``federate()`` path.

Equivalence contract (measured, not aspirational):
  * on separated populations both k-means implementations converge to
    the same partition regardless of seeding, and first-occurrence
    label canonicalization makes the ids comparable — cluster labels
    and the selected k agree *exactly*;
  * weights/KLDs agree to fp tolerance only: the device chain runs
    f32 where the oracle runs f64, and beta multiplies the KLD error
    into the weight logits;
  * aggregated params agree to f32-accumulation tolerance (the same
    bound the fused-vs-legacy federation tests use).

The fused path's "no host round-trip" claim is enforced with
``jax.transfer_guard('disallow_explicit')`` around a compiled round —
and the numpy-oracle round is asserted to *trip* the same guard, so
the guard is known to catch exactly the transfers the fused path
eliminates. The sharded twin (``multihost``) re-runs the fused-vs-
oracle trainer comparison at 8 forced CPU devices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clustering
from repro.core import kld as kldm
from repro.core.clustering import (cluster_activations,
                                   cluster_activations_jax, k_selection_bound,
                                   kmeans, silhouette)
from repro.core.federation import (federate_client_params,
                                   federate_client_params_device)
from repro.core.huscf import HuSCFConfig, HuSCFTrainer
from repro.core.latency import Cut, PAPER_DEVICES
from repro.core.splitting import group_by_profile
from repro.data import build_scenario
from repro.models.gan import DISC_MIDDLE_FEATURES

MODULE = "test_cluster_fused"


# --------------------------------------------------------------------------
# bugfix regressions (satellites)
# --------------------------------------------------------------------------

def test_silhouette_singleton_scores_zero():
    """Regression: a singleton cluster used to get a=0 => s_i=1 (a
    perfect score); the standard convention is s_i=0."""
    x = np.array([[0.0, 0.0], [10.0, 0.0], [20.0, 0.0]])
    assert silhouette(x, np.array([0, 1, 2])) == 0.0


def test_silhouette_selection_not_biased_to_fragmentation():
    """Regression: on two noisy blobs the singleton s_i=1 bias made the
    old k-selection prefer a fragmenting k=3 (isolating a point) over
    the true k=2; the fixed convention picks 2."""
    rng = np.random.default_rng(2)
    x = np.vstack([rng.normal(0, 1.0, (3, 4)),
                   rng.normal(0, 1.0, (3, 4)) + 2.0])
    mu, sd = x.mean(0), x.std(0) + 1e-8
    z = (x - mu) / sd

    def silhouette_biased(z, labels):     # the pre-fix convention
        d = np.sqrt(np.maximum(((z[:, None, :] - z[None]) ** 2).sum(-1), 0.0))
        uniq, s = np.unique(labels), np.zeros(len(z))
        for i in range(len(z)):
            same = labels == labels[i]
            same[i] = False
            a = d[i][same].mean() if same.any() else 0.0
            b = min(d[i][labels == c].mean() for c in uniq if c != labels[i])
            s[i] = 0.0 if max(a, b) == 0 else (b - a) / max(a, b)
        return s.mean()

    sils, biased = {}, {}
    for kk in (2, 3):
        labels, _, _ = kmeans(z, kk, seed=0)
        sils[kk] = silhouette(z, labels)
        biased[kk] = silhouette_biased(z, labels)
    assert biased[3] > biased[2]          # the bug: fragmentation wins
    assert sils[2] > sils[3]              # the fix: true k wins
    assert cluster_activations(x, seed=0).k == 2


def test_kmeans_empty_cluster_reseeds_distinct(monkeypatch):
    """Regression: duplicate initial centers empty k-2 clusters in the
    first Lloyd update; the stale-d2 re-seed put every empty cluster at
    the same farthest point (duplicate centers); the fix re-seeds at
    distinct points measured against the updated centers."""
    rng = np.random.default_rng(0)
    x = np.vstack([rng.normal(0, 0.1, (6, 3)) - 4,
                   rng.normal(0, 0.1, (6, 3)) + 4])
    monkeypatch.setattr(clustering, "kmeans_pp_init",
                        lambda x_, k, rng_: np.stack(
                            [x_[0], x_[6], x_[0], x_[0]]))
    for iters in (1, 50):                 # one update, and converged
        _, centers, _ = kmeans(x, 4, seed=0, iters=iters)
        d2 = ((centers[:, None] - centers[None]) ** 2).sum(-1)
        assert d2[~np.eye(4, dtype=bool)].min() > 1e-6, \
            f"duplicate centers after iters={iters}"


def test_canonicalize_labels_first_occurrence_order():
    canon, _ = clustering.canonicalize_labels(np.array([2, 2, 0, 5, 0, 2]))
    np.testing.assert_array_equal(canon, [0, 0, 1, 2, 1, 0])


def test_federation_weights_logspace_matches_literal_small_beta():
    """Where n_k exp(-beta KLD) does not underflow, the log-space form
    is the same formula."""
    rng = np.random.default_rng(0)
    klds = rng.random(8) * 0.5
    sizes = rng.integers(50, 700, 8)
    labels = np.array([0, 0, 0, 1, 1, 1, 1, 2])
    for beta in (0.0, 1.0, 10.0):
        raw = sizes.astype(np.float64) * np.exp(-beta * klds)
        want = np.zeros(8)
        for c in np.unique(labels):
            m = labels == c
            want[m] = raw[m] / raw[m].sum()
        got = kldm.federation_weights(klds, sizes, labels, beta=beta)
        np.testing.assert_allclose(got, want, rtol=1e-12)
        np.testing.assert_allclose(kldm.global_weights(klds, sizes, beta=beta),
                                   raw / raw.sum(), rtol=1e-12)


def test_federation_weights_no_underflow_at_paper_beta():
    """Regression: at beta=150, exp(-beta KLD) underflows past KLD ~ 5
    and the old path silently went *uniform*, discarding n_k. Equal
    KLDs must stay size-proportional at any beta."""
    klds = np.full(4, 8.0)                # exp(-1200) == 0.0 in f64
    sizes = np.array([100, 300, 500, 100])
    labels = np.zeros(4, np.int64)
    w = kldm.federation_weights(klds, sizes, labels, beta=150.0)
    np.testing.assert_allclose(w, sizes / sizes.sum(), rtol=1e-12)
    g = kldm.global_weights(klds, sizes, beta=150.0)
    np.testing.assert_allclose(g, sizes / sizes.sum(), rtol=1e-12)
    # and with spread KLDs the ordering still holds (no all-zero denom)
    klds = np.array([6.0, 7.0, 8.0, 9.0])
    w = kldm.federation_weights(klds, np.full(4, 100), labels, beta=150.0)
    assert np.all(np.isfinite(w)) and abs(w.sum() - 1.0) < 1e-12
    assert w[0] > w[1] > w[2] > w[3]


# --------------------------------------------------------------------------
# device cluster+weight chain vs the numpy oracle
# --------------------------------------------------------------------------

def _blobs(n_per, offs, dim, seed, scale=0.3):
    rng = np.random.default_rng(seed)
    return np.vstack([rng.normal(0, scale, (n_per, dim)) + off
                      for off in offs]).astype(np.float32)


@pytest.mark.parametrize("seed,offs", [(0, (-8, 0, 8)), (1, (-6, 6)),
                                       (2, (-9, -3, 3, 9))])
def test_cluster_weight_device_matches_numpy(seed, offs):
    acts = _blobs(4, offs, 32, seed)
    K = acts.shape[0]
    sizes = np.random.default_rng(seed + 100).integers(50, 700, K)

    res = cluster_activations(acts, seed=0)
    w_np, klds_np = kldm.activation_weights(acts, sizes, res.labels,
                                            beta=150.0)
    labels_j, k_j, sil_j = cluster_activations_jax(
        jnp.asarray(acts), jax.random.PRNGKey(seed))
    bound = k_selection_bound(K)
    w_j, klds_j = kldm.activation_weights_jax(
        jnp.asarray(acts), jnp.asarray(sizes, jnp.float32), labels_j,
        bound, 150.0)

    assert int(k_j) == res.k == len(offs)
    np.testing.assert_array_equal(np.asarray(labels_j), res.labels)
    np.testing.assert_allclose(float(sil_j), res.silhouette, atol=1e-4)
    np.testing.assert_allclose(np.asarray(klds_j), klds_np, atol=1e-5)
    np.testing.assert_allclose(np.asarray(w_j), w_np, atol=1e-4)


def test_cluster_jax_fixed_k_fallback_and_kernel():
    # fixed k honored (and its labels match the oracle's)
    acts = _blobs(5, (-5, 5), 16, 3)
    labels_j, k_j, _ = cluster_activations_jax(jnp.asarray(acts),
                                               jax.random.PRNGKey(0), k=2)
    res = cluster_activations(acts, k=2, seed=0)
    assert int(k_j) == 2
    np.testing.assert_array_equal(np.asarray(labels_j), res.labels)
    # Pallas kmeans_assign twin gives the same assignment
    labels_k, k_k, _ = cluster_activations_jax(
        jnp.asarray(acts), jax.random.PRNGKey(0), k=2, use_kernel=True)
    assert int(k_k) == 2
    np.testing.assert_array_equal(np.asarray(labels_k), np.asarray(labels_j))
    # unstructured activations: weak silhouette -> k=1, labels zero
    noise = np.random.default_rng(4).normal(0, 1, (12, 16)).astype(np.float32)
    labels_n, k_n, sil_n = cluster_activations_jax(
        jnp.asarray(noise), jax.random.PRNGKey(0), min_silhouette=0.3)
    assert int(k_n) == 1 and float(sil_n) == 0.0
    assert not np.asarray(labels_n).any()


def _tiny_population():
    devs = [PAPER_DEVICES[0]] * 2 + [PAPER_DEVICES[1]] * 2
    cuts = [Cut(1, 3, 1, 3)] * 2 + [Cut(2, 4, 2, 4)] * 2
    return group_by_profile(devs, cuts)


def test_device_weight_segments_matches_host():
    """The in-jit A/seg_ids assembly reproduces the host-built round:
    same weights/labels in, allclose aggregated params out."""
    groups = _tiny_population()
    rng = np.random.default_rng(0)
    client_params = {}
    for g in groups:
        owned = list(range(g.cut.g_h)) + list(range(g.cut.g_t, 5))
        client_params[g.name] = {"G": {
            str(l): {"w": jnp.asarray(rng.normal(0, 1, (g.size, 3, 4)),
                                      jnp.float32)}
            for l in owned}}
    weights = rng.random(4)
    labels = np.array([0, 1, 0, 1])
    host = federate_client_params(groups, client_params, weights, labels,
                                  n_layers={"G": 5})
    dev = federate_client_params_device(
        groups, client_params, jnp.asarray(weights, jnp.float32),
        jnp.asarray(labels, jnp.int32), 2, n_layers={"G": 5})
    hl, ht = jax.tree_util.tree_flatten(host)
    dl, dt = jax.tree_util.tree_flatten(dev)
    assert ht == dt
    for h, d in zip(hl, dl):
        np.testing.assert_allclose(np.asarray(d), np.asarray(h), atol=1e-6)
    # a label id below the bound that never occurs = empty segments only
    dev3 = federate_client_params_device(
        groups, client_params, jnp.asarray(weights, jnp.float32),
        jnp.asarray(labels, jnp.int32), 3, n_layers={"G": 5})
    for h, d in zip(hl, jax.tree_util.tree_leaves(dev3)):
        np.testing.assert_allclose(np.asarray(d), np.asarray(h), atol=1e-6)


# --------------------------------------------------------------------------
# trainer: fused_cluster vs the numpy-oracle federate
# --------------------------------------------------------------------------

def _make_trainer(fused_cluster: bool, mesh=None, n_clients: int = 4,
                  seed: int = 0):
    clients = build_scenario("2dom_iid", num_clients=n_clients, base_size=16,
                             seed=0)
    devices = [PAPER_DEVICES[i % 2] for i in range(n_clients)]
    cuts = [Cut(1, 3, 1, 3) if i % 2 == 0 else Cut(2, 4, 2, 4)
            for i in range(n_clients)]
    cfg = HuSCFConfig(batch=2, steps_per_epoch=2, federate_every=10 ** 6,
                      seed=seed, warmup_fed_rounds=0,
                      fused_cluster=fused_cluster)
    return HuSCFTrainer(clients, devices, cuts=cuts, config=cfg,
                        fed_mesh=mesh)


def _ema_blobs(n_clients: int, seed: int = 7):
    """Well-separated synthetic EMA: both k-means implementations
    converge to the same partition regardless of seeding."""
    rng = np.random.default_rng(seed)
    half = n_clients // 2
    return np.vstack(
        [rng.normal(0, 0.3, (half, DISC_MIDDLE_FEATURES)) - 5,
         rng.normal(0, 0.3, (n_clients - half, DISC_MIDDLE_FEATURES)) + 5]
    ).astype(np.float32)


def _client_state(tr):
    return jax.tree_util.tree_map(
        np.asarray, {net: tr.state[net]["client"] for net in ("G", "D")})


@pytest.fixture(scope="module")
def fedpair():
    """(fused, oracle) trainers with identical params and an injected
    common EMA, plus their first clustered-round diagnostics/states."""
    fused, oracle = _make_trainer(True), _make_trainer(False)
    fused.train_steps(1)
    oracle.train_steps(1)
    blob = _ema_blobs(4)
    fused._mid_ema = jnp.asarray(blob)
    oracle._mid_ema = jnp.asarray(blob)
    df, do = fused.federate(), oracle.federate()
    return fused, oracle, df, do, (_client_state(fused),
                                   _client_state(oracle))


def test_fused_cluster_matches_numpy_oracle(fedpair):
    _, _, df, do, (sf, so) = fedpair
    assert df["mode"] == do["mode"] == "clustered"
    assert int(df["k"]) == do["k"] == 2
    np.testing.assert_array_equal(np.asarray(df["labels"]), do["labels"])
    np.testing.assert_allclose(float(df["silhouette"]), do["silhouette"],
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(df["weights"]), do["weights"],
                               atol=1e-4)
    # device weights still sum to one within each cluster
    w, labels = np.asarray(df["weights"]), np.asarray(df["labels"])
    for c in np.unique(labels):
        np.testing.assert_allclose(w[labels == c].sum(), 1.0, atol=1e-6)
    # aggregated params within f32-accumulation tolerance of the oracle
    fl, ft = jax.tree_util.tree_flatten(sf)
    ol, ot = jax.tree_util.tree_flatten(so)
    assert ft == ot
    for f, o in zip(fl, ol):
        np.testing.assert_allclose(f, o, atol=5e-4, rtol=0)


def test_fused_cluster_zero_host_transfers(fedpair):
    """The acceptance property: with everything compiled, a fused
    clustered round runs under jax.transfer_guard('disallow_explicit')
    — no host<->device movement of activations/labels/weights — while
    the numpy-oracle round trips the very same guard (so the guard is
    known to see the transfers being eliminated)."""
    fused, oracle, _, _, _ = fedpair
    fused.train_steps(1)
    oracle.train_steps(1)
    with jax.transfer_guard("disallow_explicit"):
        diag = fused.federate()
    assert diag["mode"] == "clustered"
    with pytest.raises(Exception, match="[Dd]isallowed.*transfer"):
        with jax.transfer_guard("disallow_explicit"):
            oracle.federate()


def test_fused_cluster_before_training_raises():
    tr = _make_trainer(True)
    with pytest.raises(RuntimeError, match="EMA is empty"):
        tr.federate()


# --------------------------------------------------------------------------
# sharded twin (multihost fixture): fused cluster round on a client-axis
# mesh vs the numpy oracle, 8 forced CPU devices
# --------------------------------------------------------------------------

def _check_fused_cluster_sharded():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from test_cluster_fused import _client_state, _ema_blobs, _make_trainer
    from repro.launch.mesh import make_federation_mesh
    assert jax.device_count() >= 8

    mesh = make_federation_mesh(2)      # group size 2 -> divisible
    tr_fused = _make_trainer(True, mesh=mesh)
    tr_oracle = _make_trainer(False, mesh=mesh)
    tr_fused.train_steps(1)
    tr_oracle.train_steps(1)
    blob = _ema_blobs(4)
    rep = NamedSharding(mesh, P())
    tr_fused._mid_ema = jax.device_put(jnp.asarray(blob), rep)
    tr_oracle._mid_ema = jax.device_put(jnp.asarray(blob), rep)
    df, do = tr_fused.federate(), tr_oracle.federate()
    assert int(df["k"]) == do["k"] == 2
    np.testing.assert_array_equal(np.asarray(df["labels"]), do["labels"])
    np.testing.assert_allclose(np.asarray(df["weights"]), do["weights"],
                               atol=1e-4)
    ff = jax.tree_util.tree_flatten(_client_state(tr_fused))
    oo = jax.tree_util.tree_flatten(_client_state(tr_oracle))
    assert ff[1] == oo[1]
    for f, o in zip(ff[0], oo[0]):
        np.testing.assert_allclose(f, o, atol=5e-4, rtol=0)


def test_fused_cluster_sharded_multihost(multihost):
    multihost(MODULE, "_check_fused_cluster_sharded")
