"""Device-resident training epochs (DESIGN.md §Device-resident epochs):
scan-fused multi-step loop vs the per-step oracle, on-device batch
sampling from the padded `DeviceDataset`, the in-carry middle-activation
EMA, and the `generate()` label-cycling regression.

Equivalence contract (measured, not aspirational):
  * the on-device PRNG/sampling/EMA stream is *bit-identical* between
    the fused scan and the per-step oracle — after one step from a
    common state the EMAs are exactly equal;
  * the scan carry is bit-exact: one scan of N steps == N scans of one
    step (same compiled body), which pins state/key/EMA threading;
  * params only match to XLA fusion noise amplified by Adam's step-1
    ~sign(g) update (~lr per step): the backward pass compiles to
    different fusions inside scan vs a standalone jit, so fused-vs-
    oracle parameter deltas grow like a few x lr per step and the
    multi-step comparison uses loose tolerances on purpose.

The sharded twin (``multihost``) reuses the PR 2 fixture: a trainer
whose fed_mesh both stages the dataset rows and runs federation.
"""
import functools

import jax
import numpy as np
import pytest

from repro.core import HuSCFConfig, HuSCFTrainer, PAPER_DEVICES
from repro.core.latency import Cut
from repro.core.splitting import group_by_profile
from repro.data import ClientSpec, build_scenario, sample_batch, stage_clients

MODULE = "test_train_fused"
LR = 2e-4


def _make_trainer(fused: bool, mesh=None, n_clients: int = 4, seed: int = 0,
                  batch: int = 2, epoch_unroll=None):
    clients = build_scenario("2dom_iid", num_clients=n_clients, base_size=16,
                             seed=0)
    devices = [PAPER_DEVICES[i % 2] for i in range(n_clients)]
    cuts = [Cut(1, 3, 1, 3) if i % 2 == 0 else Cut(2, 4, 2, 4)
            for i in range(n_clients)]
    cfg = HuSCFConfig(batch=batch, steps_per_epoch=2, federate_every=10 ** 6,
                      seed=seed, fused_epoch=fused,
                      epoch_unroll=epoch_unroll)
    return HuSCFTrainer(clients, devices, cuts=cuts, config=cfg,
                        fed_mesh=mesh)


def _tree_close(got, want, atol):
    gl, gt = jax.tree_util.tree_flatten(got)
    wl, wt = jax.tree_util.tree_flatten(want)
    assert gt == wt
    for g, w in zip(gl, wl):
        g, w = np.asarray(g, np.float64), np.asarray(w, np.float64)
        if atol == 0.0:
            assert np.array_equal(g, w), "expected byte-identical trees"
        else:
            np.testing.assert_allclose(g, w, atol=atol, rtol=0)


@pytest.fixture(scope="module")
def pair():
    """(fused, oracle) trainers sharing topology, data, and PRNG seed,
    plus their first-step observations — advancing both one step here
    keeps every test on the fixture self-sufficient (no dependence on
    which test runs first)."""
    fused, oracle = _make_trainer(True), _make_trainer(False)
    first = (fused.train_steps(1), oracle.train_steps(1),
             fused.middle_activations(), oracle.middle_activations())
    return fused, oracle, first


def test_fused_matches_oracle_single_step(pair):
    fused, oracle, (mf, mo, ema_f, ema_o) = pair
    # identical PRNG stream -> identical batches -> identical forward
    # pass: the middle-activation EMA agrees to the bit.
    np.testing.assert_array_equal(ema_f, ema_o)
    for k in mf:
        np.testing.assert_allclose(mf[k], mo[k], rtol=1e-5)
    # params: Adam's step-1 update is ~sign(grad) * lr, so backward
    # fusion noise lands as O(lr) deltas — bound, don't bit-compare.
    _tree_close(fused.state, oracle.state, atol=20 * LR)


def test_fused_matches_oracle_ema_blend(pair):
    """A step past the fixture's first exercises the 0.8/0.2 blend:
    host-side numpy EMA (oracle) vs the in-carry device EMA (fused)
    stay together."""
    fused, oracle, _ = pair
    fused.train_steps(1)
    oracle.train_steps(1)
    assert int(np.asarray(fused.state["step"])) >= 2  # blend branch ran
    np.testing.assert_allclose(fused.middle_activations(),
                               oracle.middle_activations(),
                               atol=1e-3, rtol=0)
    _tree_close(fused.state, oracle.state, atol=0.05)


def test_scan_carry_bit_exact():
    """One scan of two steps == two scans of one step, to the bit —
    the (state, rng, mid_ema) carry threads exactly. Pinned to
    epoch_unroll=1 (the accelerator configuration): the scan body
    compiles once regardless of trip count, whereas the CPU-default
    full unroll fuses across steps and only agrees to tolerance (the
    `pair` tests above)."""
    a = _make_trainer(True, seed=3, epoch_unroll=1)
    b = _make_trainer(True, seed=3, epoch_unroll=1)
    ma = a.train_steps(2)
    b.train_steps(1)
    mb = b.train_steps(1)
    _tree_close(a.state, b.state, atol=0.0)
    np.testing.assert_array_equal(a.middle_activations(),
                                  b.middle_activations())
    assert ma == mb


def test_device_dataset_gather_stays_in_bounds():
    """Padded rows carry a -1 label sentinel; the sampler draws indices
    in [0, counts[k]) so no batch may ever contain it."""
    rng = np.random.default_rng(0)
    sizes = [3, 9, 5, 9]
    clients = [ClientSpec(i, "gratings",
                          rng.normal(size=(n, 28, 28, 1)).astype(np.float32),
                          rng.integers(0, 10, n).astype(np.int64))
               for i, n in enumerate(sizes)]
    devices = [PAPER_DEVICES[0]] * 4
    cuts = [Cut(1, 3, 1, 3)] * 4
    groups = group_by_profile(devices, cuts)
    ds = stage_clients(groups, clients)
    (gname,) = ds.order
    assert ds.images[gname].shape == (4, 9, 28, 28, 1)
    assert np.asarray(ds.counts[gname]).tolist() == sizes
    assert (np.asarray(ds.labels[gname]) == -1).sum() == sum(
        max(sizes) - n for n in sizes)
    sample = jax.jit(functools.partial(sample_batch, batch=16, z_dim=100,
                                       num_classes=10))
    for i in range(8):
        batch = sample(ds, jax.random.PRNGKey(i))
        y = np.asarray(batch["real_y"][gname])
        assert y.shape == (4, 16)
        assert (y >= 0).all(), "sampler read a padded row"
        assert np.isfinite(np.asarray(batch["real_img"][gname])).all()


def test_generate_returns_exact_labels_nondivisible(pair):
    """Regression: with >1 profile group and len(labels) not divisible
    by the per-round yield, the old np.resize window made every group
    recycle the same labels — requested labels must come back exactly,
    in order."""
    fused, _, _ = pair
    assert len(fused.groups) > 1
    for n in (7, 13):
        labels = (np.arange(n) * 3) % 10
        imgs, labs = fused.generate(3, labels)
        assert imgs.shape == (n, 28, 28, 1)
        np.testing.assert_array_equal(labs, labels)


# --------------------------------------------------------------------------
# sharded twin (PR 2 multihost fixture): the fed mesh stages the
# dataset rows and the step + federation share one device set
# --------------------------------------------------------------------------

def _check_fused_epoch_sharded():
    import jax
    from test_train_fused import _make_trainer, _tree_close
    from repro.launch.mesh import make_federation_mesh
    assert jax.device_count() >= 8
    import numpy as np

    mesh = make_federation_mesh(2)      # group size 2 -> divisible
    tr_mesh = _make_trainer(True, mesh=mesh)
    tr_none = _make_trainer(True)
    # the staged rows really shard over the client axis
    g0 = tr_mesh.groups[0].name
    spec = tr_mesh._dataset.images[g0].sharding.spec
    assert spec[0] == "data", f"dataset rows not sharded: {spec}"
    tr_mesh.train_steps(2)
    tr_none.train_steps(2)
    np.testing.assert_allclose(tr_mesh.middle_activations(),
                               tr_none.middle_activations(),
                               atol=1e-3, rtol=0)
    _tree_close(tr_mesh.state, tr_none.state, atol=0.05)
    # federation rides the same mesh (sharded round vs single-device)
    tr_mesh.federate()
    tr_none.federate()
    for net in ("G", "D"):
        _tree_close(tr_mesh.state[net]["client"],
                    tr_none.state[net]["client"], atol=0.05)


def test_fused_epoch_sharded_multihost(multihost):
    multihost(MODULE, "_check_fused_epoch_sharded")
