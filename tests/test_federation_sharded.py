"""Client-axis-sharded federation rounds vs the fused single-device
path and the legacy loop, on forced multi-device CPU.

Every multi-device case is a plain ``_check_*`` function dispatched
through the ``multihost`` fixture (see tests/conftest.py): in the
ordinary 1-device suite each check runs in a subprocess that forces 8
host CPU devices before jax import; under scripts/ci_smoke.sh's second
pytest invocation (flag already set) they run inline. Checks build
meshes of 2/4/8 devices out of the forced 8 via
``make_federation_mesh``.

Matrix: heterogeneous cuts (4 profile groups), >= 3 clusters, client
counts both divisible (16) and non-divisible (10) by the mesh — the
latter exercising ``sharding.policy.client_axes``'s sanitize fallback
to the unsharded path — plus plan-cache keying on (mesh identity,
chunk_size, cohort_size) and the ``mesh=None`` default staying
byte-identical. The chunk-streamed round's own sharded matrix lives
in tests/test_federation_chunked.py.
"""
import numpy as np
import pytest

MODULE = "test_federation_sharded"
N_CLIENTS = 16          # divisible by every mesh size {2, 4, 8}
N_PROFILES = 4          # heterogeneous cuts -> 4 distinct owned-layer sets
N_CLUSTERS = 3


def _population(n_clients, seed=0):
    from test_federation_fused import build_population
    groups, params = build_population(n_clients, N_PROFILES, seed=seed)
    K = sum(g.size for g in groups)
    rng = np.random.default_rng(seed + 1)
    return groups, params, rng.random(K), np.arange(K) % N_CLUSTERS


def _assert_trees_equal(got, want, atol=0.0):
    import jax
    gl, gt = jax.tree_util.tree_flatten(got)
    wl, wt = jax.tree_util.tree_flatten(want)
    assert gt == wt
    for g, w in zip(gl, wl):
        g, w = np.asarray(g, np.float32), np.asarray(w, np.float32)
        if atol == 0.0:
            assert np.array_equal(g, w), "expected byte-identical trees"
        else:
            np.testing.assert_allclose(g, w, atol=atol, rtol=0)


# --------------------------------------------------------------------------
# multi-device check bodies (run under >= 8 forced CPU devices)
# --------------------------------------------------------------------------

def _check_equivalence_matrix():
    """sharded(2/4/8 dev) == fused (<= 1e-6 max-abs) == legacy; the
    Pallas kernel per-shard path agrees; 1-device mesh is the fallback
    (byte-identical to fused); fedavg rides the same plan."""
    import jax
    from repro.core.federation import federate_client_params, fedavg_uniform
    from repro.launch.mesh import make_federation_mesh
    from test_federation_fused import N_LAYERS
    assert jax.device_count() >= 8
    groups, params, weights, labels = _population(N_CLIENTS)

    def fed(**kw):
        return federate_client_params(groups, params, weights, labels,
                                      n_layers=N_LAYERS, **kw)

    legacy = fed(fused=False)
    fused = fed()
    _assert_trees_equal(fused, legacy, atol=1e-5)

    for nd in (2, 4, 8):
        mesh = make_federation_mesh(nd)
        _assert_trees_equal(fed(mesh=mesh), fused, atol=1e-6)
    mesh8 = make_federation_mesh(8)
    _assert_trees_equal(fed(mesh=mesh8, use_kernel=True), fused, atol=1e-6)
    # 1-device mesh: sanitize drops the size-1 axis -> unsharded path
    _assert_trees_equal(fed(mesh=make_federation_mesh(1)), fused, atol=0.0)
    # degenerate FedAvg through the same sharded plan
    sizes = np.random.default_rng(7).integers(10, 100,
                                              sum(g.size for g in groups))
    want = fedavg_uniform(groups, params, sizes, n_layers=N_LAYERS)
    got = fedavg_uniform(groups, params, sizes, n_layers=N_LAYERS,
                         mesh=mesh8)
    _assert_trees_equal(got, want, atol=1e-6)


def _check_non_divisible_fallback():
    """10 clients: a 2-device mesh shards (10 % 2 == 0); 4/8-device
    meshes hit sanitize's divisibility fallback — plan reports no
    client axes and the result is byte-identical to the fused path."""
    import jax
    from repro.core.federation import (federate_client_params,
                                       get_federation_plan)
    from repro.launch.mesh import make_federation_mesh
    from test_federation_fused import N_LAYERS
    assert jax.device_count() >= 8
    groups, params, weights, labels = _population(10, seed=3)
    tmpl = {g.name: params[g.name]["G"] for g in groups}

    def fed(**kw):
        return federate_client_params(groups, params, weights, labels,
                                      n_layers=N_LAYERS, **kw)

    fused = fed()
    m2 = make_federation_mesh(2)
    assert get_federation_plan(groups, "G", 5, tmpl,
                               mesh=m2)._client_axes == "data"
    _assert_trees_equal(fed(mesh=m2), fused, atol=1e-6)
    for nd in (4, 8):
        mesh = make_federation_mesh(nd)
        plan = get_federation_plan(groups, "G", 5, tmpl, mesh=mesh)
        assert plan._client_axes is None, \
            f"{nd}-device mesh must fall back for 10 clients"
        _assert_trees_equal(fed(mesh=mesh), fused, atol=0.0)


def _check_plan_cache_mesh_identity():
    """Plans are cached per (mesh identity, chunk_size, cohort_size):
    distinct meshes (and None) get distinct plans; an equal mesh (same
    devices + axis names, rebuilt) reuses the cached one; the chunked
    and cohort variants of the same mesh key separately (their scan /
    recv-select bake different programs)."""
    import jax
    from repro.core.federation import get_federation_plan
    from repro.launch.mesh import make_federation_mesh
    assert jax.device_count() >= 8
    groups, params, _, _ = _population(N_CLIENTS)
    tmpl = {g.name: params[g.name]["G"] for g in groups}
    cache = {}
    p_none = get_federation_plan(groups, "G", 5, tmpl, plan_cache=cache)
    p2 = get_federation_plan(groups, "G", 5, tmpl, plan_cache=cache,
                             mesh=make_federation_mesh(2))
    p4 = get_federation_plan(groups, "G", 5, tmpl, plan_cache=cache,
                             mesh=make_federation_mesh(4))
    assert len(cache) == 3
    assert len({id(p_none), id(p2), id(p4)}) == 3
    # Mesh hashes by device assignment + axis names -> rebuilding an
    # equal mesh hits the same plan.
    p2b = get_federation_plan(groups, "G", 5, tmpl, plan_cache=cache,
                              mesh=make_federation_mesh(2))
    assert p2b is p2 and len(cache) == 3
    assert p_none._client_axes is None and p2._client_axes == "data"
    # (chunk_size, cohort_size) join the key on the same mesh
    p2c = get_federation_plan(groups, "G", 5, tmpl, plan_cache=cache,
                              mesh=make_federation_mesh(2), chunk_size=2)
    p2cs = get_federation_plan(groups, "G", 5, tmpl, plan_cache=cache,
                               mesh=make_federation_mesh(2), chunk_size=2,
                               cohort_size=8)
    assert len(cache) == 5 and p2c is not p2 and p2cs is not p2c
    assert p2c._chunk_axes == "data"      # 4 per group, divisible by 2
    assert get_federation_plan(groups, "G", 5, tmpl, plan_cache=cache,
                               mesh=make_federation_mesh(2),
                               chunk_size=2, cohort_size=8) is p2cs
    assert len(cache) == 5


def _check_trainer_sharded_rounds():
    """HuSCFTrainer wiring: a trainer with fed_mesh set runs its FedAvg
    warmup round and its clustered round through the sharded path and
    lands within 1e-6 of an identically-seeded unsharded twin."""
    import jax
    from repro.core import HuSCFConfig, HuSCFTrainer, PAPER_DEVICES
    from repro.core.latency import Cut
    from repro.data import build_scenario
    from repro.launch.mesh import make_federation_mesh
    assert jax.device_count() >= 8
    clients = build_scenario("2dom_iid", num_clients=8, base_size=24, seed=0)
    devices = [PAPER_DEVICES[i % 3] for i in range(8)]
    cuts = [Cut(1, 3, 1, 3) if i % 2 == 0 else Cut(2, 4, 2, 4)
            for i in range(8)]
    cfg = HuSCFConfig(batch=4, steps_per_epoch=1, federate_every=1,
                      warmup_fed_rounds=1, seed=0)

    def make(mesh):
        tr = HuSCFTrainer(clients, devices, cuts=cuts, config=cfg,
                          fed_mesh=mesh)
        tr.train_steps(1)
        return tr

    tr_mesh = make(make_federation_mesh(4))     # 8 clients % 4 == 0
    tr_none = make(None)
    for expected_mode in ("fedavg", "clustered"):
        assert tr_mesh.federate()["mode"] == expected_mode
        assert tr_none.federate()["mode"] == expected_mode
        for net in ("G", "D"):
            _assert_trees_equal(tr_mesh.state[net]["client"],
                                tr_none.state[net]["client"], atol=1e-6)


# --------------------------------------------------------------------------
# pytest wrappers
# --------------------------------------------------------------------------

def test_sharded_equivalence_matrix(multihost):
    multihost(MODULE, "_check_equivalence_matrix")


def test_sharded_non_divisible_fallback(multihost):
    multihost(MODULE, "_check_non_divisible_fallback")


def test_plan_cache_keys_on_mesh_identity(multihost):
    multihost(MODULE, "_check_plan_cache_mesh_identity")


def test_trainer_sharded_rounds(multihost):
    multihost(MODULE, "_check_trainer_sharded_rounds")


def test_mesh_none_default_byte_identical():
    """The mesh=None default (and a trivial 1-device mesh) must leave
    today's single-device path untouched — runs inline on any device
    count, no multihost needed."""
    from repro.core.federation import (federate_client_params,
                                       get_federation_plan)
    from repro.launch.mesh import make_federation_mesh
    from test_federation_fused import N_LAYERS
    groups, params, weights, labels = _population(6)

    def fed(**kw):
        return federate_client_params(groups, params, weights, labels,
                                      n_layers=N_LAYERS, **kw)

    base = fed()
    _assert_trees_equal(fed(mesh=None), base, atol=0.0)
    m1 = make_federation_mesh(1)
    plan = get_federation_plan(groups, "G", 5,
                               {g.name: params[g.name]["G"] for g in groups},
                               mesh=m1)
    assert plan._client_axes is None
    _assert_trees_equal(fed(mesh=m1), base, atol=0.0)
