"""Property tests (hypothesis) for ``FederationPlan``: the
flatten -> unflatten roundtrip is the identity for random pytrees and
cuts, and ``weight_segments`` rows are normalized within each
(layer, cluster) block — including the zero-weight-sum fallback.

Cases are derived deterministically from hypothesis-drawn integers
(seed + structure knobs) so each example is reproducible from its
shrunk values; the plan machinery is cut-agnostic, so cuts range over
the general ``0 <= h <= t <= n_layers`` contract, not just the
paper-valid middle-on-server cuts.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (bare env)")
from hypothesis import assume, given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import federation as fed
from repro.core.federation import FederationPlan
from repro.core.latency import Cut, PAPER_DEVICES
from repro.core.splitting import ProfileGroup, client_owned_layers


def _random_case(seed: int, n_layers: int, n_groups: int):
    """Deterministic random population: per-layer leaf pytrees (1-3
    leaves, rank 1-2, dims 1-4 — shared across groups, as the plan
    requires), per-group random cuts and sizes, and the stacked f32
    template. Returns (groups, template)."""
    rng = np.random.default_rng(seed)
    layer_shapes = {
        l: [tuple(rng.integers(1, 5, rng.integers(1, 3)))
            for _ in range(rng.integers(1, 4))]
        for l in range(n_layers)}
    groups = []
    cid = 0
    for gi in range(n_groups):
        h = int(rng.integers(0, n_layers + 1))
        t = int(rng.integers(h, n_layers + 1))
        size = int(rng.integers(1, 4))
        ids = list(range(cid, cid + size))
        cid += size
        groups.append(ProfileGroup(f"g{gi}|{h}-{t}", PAPER_DEVICES[0],
                                   Cut(h, t, h, t), ids))
    template = {
        g.name: {
            str(l): {f"w{i}": rng.standard_normal(
                        (g.size,) + shp).astype(np.float32)
                     for i, shp in enumerate(layer_shapes[l])}
            for l in client_owned_layers((g.cut.g_h, g.cut.g_t), n_layers)}
        for g in groups}
    return groups, template


@given(seed=st.integers(0, 2**31 - 1), n_layers=st.integers(2, 4),
       n_groups=st.integers(1, 3))
@settings(max_examples=15, deadline=None)
def test_flatten_unflatten_roundtrip_is_identity(seed, n_layers, n_groups):
    """With the identity weight matrix (every copy its own segment,
    seg_ids = own row), _unflatten(_flatten(params)) == params exactly:
    the flat layout loses nothing and the zero-filled non-owned runs
    are never read back."""
    groups, template = _random_case(seed, n_layers, n_groups)
    assume(any(template[g.name] for g in groups))   # someone owns a layer
    plan = FederationPlan(groups, "G", n_layers, template)
    theta = plan._flatten(template)
    assert theta.shape == (plan.n_rows, plan.n_cols)
    seg_ids = np.zeros(plan.n_copies, np.int32)
    for e in plan.entries:
        seg_ids[e.sid0:e.sid1] = np.arange(e.row0, e.row1)
    out = plan._unflatten(theta, jnp.asarray(seg_ids))
    for g in groups:
        for l, tree in template[g.name].items():
            got = jax.tree_util.tree_leaves(out[g.name][l])
            want = jax.tree_util.tree_leaves(tree)
            assert len(got) == len(want)
            for a, b in zip(got, want):
                assert a.dtype == b.dtype and a.shape == b.shape
                assert np.array_equal(np.asarray(a), b)


@given(seed=st.integers(0, 2**31 - 1), n_layers=st.integers(2, 4),
       n_groups=st.integers(1, 3), n_clusters=st.integers(1, 4),
       zero_cluster=st.booleans())
@settings(max_examples=15, deadline=None)
def test_weight_segments_rows_normalized(seed, n_layers, n_groups,
                                         n_clusters, zero_cluster):
    """Every real A row (one per (layer, cluster) block) sums to 1 with
    non-negative entries — also when a whole cluster's Eq.-15 weights
    are zero (uniform fallback) — and the _SEGMENT_PAD rows are zero."""
    groups, template = _random_case(seed, n_layers, n_groups)
    assume(any(template[g.name] for g in groups))
    plan = FederationPlan(groups, "G", n_layers, template)
    rng = np.random.default_rng(seed + 1)
    labels = rng.integers(0, n_clusters, plan.n_rows)
    weights = rng.random(plan.n_rows)
    if zero_cluster:
        weights[labels == labels[0]] = 0.0
    A, seg_ids = plan.weight_segments(weights, labels)
    assert A.shape == (A.shape[0], plan.n_rows)
    assert A.shape[0] % fed._SEGMENT_PAD == 0
    assert seg_ids.shape == (plan.n_copies,)
    n_real = int(seg_ids.max()) + 1 if plan.n_copies else 0
    if n_real:
        np.testing.assert_allclose(A[:n_real].sum(axis=1), 1.0, atol=1e-6)
        assert np.all(A >= 0)
    assert np.all(A[n_real:] == 0)
