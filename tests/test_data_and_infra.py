"""Data pipeline invariants (hypothesis), checkpointing round-trip,
optimizer behaviour, metrics edge cases, sharding policy rules."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (bare env)")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import DOMAINS, NUM_CLASSES, build_scenario, make_dataset
from repro.data.partition import paper_exclusion_plan
from repro.metrics import evaluate, fid, wald_ci
from repro.optim import adam, sgd, warmup_cosine


# --- data --------------------------------------------------------------------

@given(st.sampled_from(DOMAINS), st.integers(4, 64), st.integers(0, 3))
@settings(max_examples=12, deadline=None)
def test_dataset_range_and_labels(domain, n, seed):
    imgs, labs = make_dataset(domain, n, seed=seed)
    assert imgs.shape == (n, 28, 28, 1)
    assert imgs.min() >= -1.0 and imgs.max() <= 1.0
    assert labs.min() >= 0 and labs.max() < NUM_CLASSES


def test_domains_are_distinguishable():
    """Different domains must differ in pixel statistics (the clustering
    stage depends on it)."""
    means = []
    for d in DOMAINS:
        imgs, _ = make_dataset(d, 128, seed=0)
        pooled = imgs.reshape(128, 7, 4, 7, 4, 1).mean((2, 4, 5))
        means.append(pooled.mean(0).ravel())
    for i in range(len(DOMAINS)):
        for j in range(i + 1, len(DOMAINS)):
            assert np.abs(means[i] - means[j]).mean() > 0.02


def test_scenario_label_exclusions():
    clients = build_scenario("1dom_noniid", num_clients=10, base_size=40,
                             seed=1)
    assert len(clients) == 10
    n_missing = sum(1 for c in clients
                    if len(np.unique(c.labels)) < NUM_CLASSES)
    assert n_missing >= 5  # 40%+20% of clients have labels excluded


def test_scenario_multi_domain_split():
    clients = build_scenario("4dom_iid", num_clients=8, base_size=24, seed=0)
    doms = sorted({c.domain for c in clients})
    assert doms == sorted(DOMAINS)


@given(st.integers(4, 30))
@settings(max_examples=10, deadline=None)
def test_exclusion_plan_counts(n):
    plan = [(n // 3, 2), (n // 5, 3)]
    excl = paper_exclusion_plan(n, plan, seed=0)
    n2 = sum(1 for e in excl if len(e) == 2)
    n3 = sum(1 for e in excl if len(e) == 3)
    assert n2 == n // 3 and n3 == n // 5


# --- checkpoint --------------------------------------------------------------

def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(5, dtype=jnp.float32),
            "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.msgpack")
        save_checkpoint(path, tree, step=7)
        restored, step = load_checkpoint(path, tree)
        assert step == 7
        np.testing.assert_allclose(np.asarray(restored["a"]),
                                   np.arange(5, dtype=np.float32))
        assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_rejects_mismatch():
    tree = {"a": jnp.zeros(3)}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.msgpack")
        save_checkpoint(path, tree)
        with pytest.raises(ValueError):
            load_checkpoint(path, {"a": jnp.zeros(4)})


# --- optimizers --------------------------------------------------------------

def test_adam_converges_quadratic():
    init, update = adam(0.1)
    params = {"x": jnp.asarray(5.0)}
    state = init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: (p["x"] - 2.0) ** 2)(params)
        state, params = update(state, grads, params)
    assert abs(float(params["x"]) - 2.0) < 1e-2


def test_adam_grad_clip_bounds_update():
    init, update = adam(1.0, grad_clip=0.5)
    params = {"x": jnp.zeros(4)}
    state = init(params)
    grads = {"x": jnp.full(4, 1e6)}
    state, params = update(state, grads, params)
    assert np.all(np.isfinite(np.asarray(params["x"])))


def test_warmup_cosine_shape():
    fn = warmup_cosine(1.0, 10, 100)
    assert float(fn(jnp.asarray(0))) == 0.0
    assert float(fn(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(fn(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)


# --- metrics -----------------------------------------------------------------

def test_evaluate_perfect_predictions():
    y = np.arange(100) % 10
    rep = evaluate(y, y.copy())
    assert rep.accuracy == 1.0 and rep.fpr == 0.0 and rep.f1 == 1.0


def test_wald_ci_decreases_with_n():
    assert wald_ci(0.9, 10000) < wald_ci(0.9, 100)


def test_fid_zero_for_identical():
    rng = np.random.default_rng(0)
    f = rng.normal(0, 1, (500, 16))
    assert fid(f, f.copy()) < 1e-3


def test_fid_grows_with_shift():
    rng = np.random.default_rng(0)
    f = rng.normal(0, 1, (500, 16))
    g1 = rng.normal(0.5, 1, (500, 16))
    g2 = rng.normal(3.0, 1, (500, 16))
    assert fid(f, g1) < fid(f, g2)


# --- sharding policy ---------------------------------------------------------

def test_param_specs_divisibility():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.sharding.policy import ShardingPolicy, param_spec
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    # everything must sanitize to replicated on a 1x1 mesh... trivially ok
    spec = param_spec(mesh, ShardingPolicy(), "blocks/attn/wq", (512, 8, 64))
    assert isinstance(spec, P)


def test_sanitize_drops_nondivisible():
    import jax
    from repro.sharding.policy import sanitize
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # trivial mesh: axis size 1 -> always dropped (size 1 sharding is no-op)
    s = sanitize(mesh, (7, 13), ("data", "model"))
    assert tuple(s) == (None, None)
