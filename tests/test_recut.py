"""Online cut re-optimization + population churn through HuSCFTrainer:
reoptimize_every rounds, registry churn (leave/join), profile updates,
param migration, and FederationPlan cache invalidation.

Trainer compiles dominate this file's wall time, so each test keeps to
one trainer and at most one rebuild (a rebuild retraces the step/epoch
programs for the new grouping).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.genetic import GAConfig
from repro.core.huscf import HuSCFConfig, HuSCFTrainer
from repro.core.latency import DeviceProfile, PAPER_DEVICES
from repro.data import ClientSpec

GA = GAConfig(population_size=48, generations=8, seed=0,
              early_stop_patience=4)


def mk_clients(n, seed=0, size=64, id0=0):
    rng = np.random.default_rng(seed)
    return [ClientSpec(id0 + i, "gratings",
                       rng.normal(size=(size, 28, 28, 1)).astype(np.float32),
                       rng.integers(0, 10, size).astype(np.int64))
            for i in range(n)]


def mk_trainer(n=4, dev_mod=2, ga=GA, **cfg_kw):
    cfg = HuSCFConfig(batch=8, federate_every=1, seed=0, steps_per_epoch=1,
                      warmup_fed_rounds=0, **cfg_kw)
    clients = mk_clients(n)
    devices = [PAPER_DEVICES[i % dev_mod] for i in range(n)]
    return HuSCFTrainer(clients, devices, config=cfg, ga_config=ga)


def client_leaf(trainer, cid, net="G", layer="0"):
    for g in trainer.groups:
        if cid in g.client_ids:
            pos = g.client_ids.index(cid)
            tree = trainer.state[net]["client"][g.name][layer]
            return np.asarray(jax.tree_util.tree_leaves(tree)[0][pos])
    raise AssertionError(f"client {cid} not found")


def test_reoptimize_every_converges_then_stops_churning():
    """With unchanged profiles the per-round GA improves the incumbent
    monotonically and then goes quiet: ties must NOT churn the
    population (no regroup, no plan-cache flush) — round after round.

    Two distinct profiles keep the gene space at 16^2 = 256, so a
    128-individual population certainly finds the optimum at init and
    every per-round search can only tie against it."""
    tr = mk_trainer(dev_mod=2,
                    ga=GAConfig(population_size=128, generations=12,
                                seed=0, early_stop_patience=6),
                    reoptimize_every=1)
    tr.train_steps(1)
    recuts, lats = [], [tr.ga_latency]
    for _ in range(3):
        diag = tr.federate()
        recuts.append(diag["recut"])
        lats.append(tr.ga_latency)
    # adopted cuts only ever improve the modeled latency
    assert all(b <= a + 1e-12 for a, b in zip(lats, lats[1:]))
    # the tail rounds are ties — stable cuts, no churn
    assert recuts[-2:] == [False, False]
    assert len(tr._fed_plans) > 0          # populated, not invalidated
    plans = set(tr._fed_plans.keys())
    cuts_tail = [c.as_tuple() for c in tr.cuts]
    diag = tr.federate()
    assert diag["recut"] is False
    assert [c.as_tuple() for c in tr.cuts] == cuts_tail
    assert set(tr._fed_plans.keys()) == plans
    # the per-round search dispatch itself is transfer-free: the
    # trainer's _run_search wraps it in the guard, and directly off a
    # device key chain it must pass too
    searcher = tr._get_searcher()
    key = jax.random.PRNGKey(9)
    with jax.transfer_guard("disallow_explicit"):
        _, sub = jax.random.split(key)
        jax.block_until_ready(searcher.run(sub))


def test_churn_recut_migration_and_plan_invalidation():
    """One churn event (client 0 leaves; an unseen-profile client
    joins) must: re-derive cuts, regroup, flush the FederationPlan
    cache, keep survivors' trained params + EMA rows under compacted
    ids, seed the joiner's EMA row with the survivor mean, and leave a
    trainer that still trains/federates."""
    tr = mk_trainer(5)
    tr.train_steps(1)
    tr.federate()
    assert len(tr._fed_plans) > 0
    old_ema = np.asarray(tr._mid_ema).copy()
    surv_before = client_leaf(tr, 2)       # old client 2 -> new id 1

    fast = DeviceProfile("ultrafast", 3.0e9, 64.0, 500e6)
    joiner = mk_clients(1, seed=99, id0=5)[0]
    cuts = tr.apply_churn(leave=[0], join=[(joiner, fast)])
    assert len(tr.clients) == 5 and len(cuts) == 5
    assert any(g.profile.name == "ultrafast" for g in tr.groups)
    assert tr._fed_plans == {}             # invalidated
    assert tr.registry.n_clients == 5
    assert int(tr.registry.sizes[-1]) == joiner.n
    # survivor params + EMA rows under compacted ids; joiner EMA = mean
    np.testing.assert_array_equal(surv_before, client_leaf(tr, 1))
    new_ema = np.asarray(tr._mid_ema)
    np.testing.assert_array_equal(new_ema[:4], old_ema[1:])
    np.testing.assert_allclose(new_ema[-1], old_ema[1:].mean(0), rtol=1e-5)
    # the rebuilt trainer trains and federates under the new grouping
    tr.train_steps(1)
    diag = tr.federate()
    assert diag["mode"] in ("fedavg", "clustered")
    assert len(tr._fed_plans) > 0          # repopulated with new keys


def test_update_profile_regroups_and_keeps_identity():
    """A degraded-bandwidth report re-derives cuts; the client keeps
    its dataset/params/EMA row (identity-preserving churn). The
    per-step oracle epoch path and generate() both work against the
    rebuilt grouping."""
    tr = mk_trainer(3, fused_epoch=False)
    tr.train_steps(1)
    ema_before = tr.middle_activations().copy()
    with pytest.raises(ValueError, match="unknown client id"):
        tr.update_profile(7, PAPER_DEVICES[0])
    slow = DeviceProfile("degraded", 0.25e9, 4.0, 1.2e6)
    tr.update_profile(1, slow)
    assert tr.devices[1] is slow
    assert any(g.profile.name == "degraded" for g in tr.groups)
    assert tr._fed_plans == {}
    np.testing.assert_array_equal(tr.middle_activations(), ema_before)
    assert len(tr.clients) == 3
    tr.train_steps(1)
    labels = np.arange(8) % 10
    imgs, labs = tr.generate(2, labels)
    assert imgs.shape == (8, 28, 28, 1)
    np.testing.assert_array_equal(labs, labels)


def test_registry_churn_mapping():
    from repro.core.registry import ClientRegistry
    reg = ClientRegistry(np.array([10, 20, 30, 40]))
    new, old_of = reg.churn(leave=[1], join_sizes=[5, 6])
    assert old_of == [0, 2, 3, -1, -1]
    assert new.sizes.tolist() == [10, 30, 40, 5, 6]
    with pytest.raises(ValueError, match="unknown client ids"):
        reg.churn(leave=[9])
    with pytest.raises(ValueError, match="empty registry"):
        reg.churn(leave=[0, 1, 2, 3])
