"""Fused device-resident GA vs the host numpy oracle: solution
quality, bookkeeping conventions, and the regression fixes riding this
change (exhaustive_profile_optimum snapshot, gen-0 history)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.genetic import (CutSearcher, GAConfig, _get_search_fn,
                                exhaustive_profile_optimum, optimize_cuts)
from repro.core.latency import (DeviceProfile, PAPER_DEVICES, PAPER_SERVER,
                                all_cut_options, huscf_iteration_latency)


def paper_mix(n=100, seed=0):
    rng = np.random.default_rng(seed)
    return [PAPER_DEVICES[i] for i in rng.integers(0, 7, n)]


CFG = GAConfig(population_size=200, generations=25, seed=0,
               early_stop_patience=10)


def test_fused_quality_matches_host_on_paper_mix():
    """Acceptance bar: same seed protocol (the paper's defaults,
    population 1000) on the paper's device mix, the fused search's
    final latency must not be worse than the numpy oracle's (bitwise
    generation equivalence not required)."""
    devices = paper_mix()
    paper_cfg = GAConfig(seed=0)          # PS=1000, GEN=60, patience 15
    host = optimize_cuts(devices, batch=64, config=paper_cfg, fused=False)
    fused = optimize_cuts(devices, batch=64, config=paper_cfg, fused=True)
    assert fused.latency <= host.latency + 1e-9
    # both report the latency of the cuts they return (host f64 model)
    assert np.isclose(fused.latency,
                      huscf_iteration_latency(fused.cuts, devices,
                                              PAPER_SERVER, 64))


@pytest.mark.parametrize("fused", [False, True])
def test_history_records_generation_zero(fused):
    """history[0] is the initial population's best; history has
    generations_run + 1 entries; history[convergence_gen] is the final
    best (the documented convention, both paths)."""
    devices = paper_mix(30)
    res = optimize_cuts(devices, batch=64, config=CFG, fused=fused)
    assert len(res.history) == res.generations_run + 1
    assert 0 <= res.convergence_gen <= res.generations_run
    assert np.isclose(min(res.history), res.history[res.convergence_gen],
                      rtol=1e-6)
    # best-so-far is monotone: no later entry beats the converged one
    assert all(h >= res.history[res.convergence_gen] - 1e-9
               for h in res.history)


@pytest.mark.parametrize("fused", [False, True])
def test_zero_generations_means_initial_population(fused):
    """generations=0: the initial population is the answer and
    convergence_gen=0 unambiguously marks it."""
    devices = paper_mix(20)
    cfg = dataclasses.replace(CFG, generations=0)
    res = optimize_cuts(devices, batch=64, config=cfg, fused=fused)
    assert res.generations_run == 0
    assert res.convergence_gen == 0
    assert len(res.history) == 1
    assert np.isclose(res.history[0], res.latency, rtol=1e-6)


def test_searcher_run_is_transfer_free():
    """The staged per-round search must run under
    transfer_guard('disallow_explicit') — device key in, SearchOut
    device arrays out."""
    searcher = CutSearcher(paper_mix(50), batch=64, config=CFG)
    key = jax.random.PRNGKey(3)
    jax.block_until_ready(searcher.run(key))       # compile outside
    key2 = jax.random.PRNGKey(4)                   # staged outside too
    with jax.transfer_guard("disallow_explicit"):
        key2, sub = jax.random.split(key2)         # the trainer's chain
        out = searcher.run(sub)
        jax.block_until_ready(out)
    res = searcher.to_result(out)
    assert res.latency > 0 and len(res.cuts) == 50


def test_search_program_shared_across_populations():
    """Two device mixes with the same GA shape (7 profiles, same
    config) must reuse one compiled program — tables are arguments,
    not baked constants (the lru_cache that makes per-round re-opt
    cheap)."""
    a = CutSearcher(paper_mix(40, seed=1), batch=64, config=CFG)
    b = CutSearcher(paper_mix(90, seed=2), batch=64, config=CFG)
    assert a.n_genes == b.n_genes == 7
    assert a._search is b._search
    # and the underlying factory is the module-level cache
    assert _get_search_fn.cache_info().hits >= 1


def test_profile_reduction_rejects_conflicting_specs():
    """Two devices sharing a name but not specs would make the
    collapsed fitness evaluate a population that doesn't exist."""
    d0 = PAPER_DEVICES[0]
    clash = DeviceProfile(d0.name, d0.freq_hz * 2, d0.flops_per_cycle,
                          d0.rate_bytes_per_s)
    with pytest.raises(ValueError, match="different specs"):
        CutSearcher([d0, clash], batch=64, config=CFG)


def test_exhaustive_optimum_latency_matches_returned_cuts():
    """Regression: best_cuts used to be snapshotted mid-sweep, so the
    returned latency could belong to a different assignment. The
    returned pair must be self-consistent."""
    for n, seed in ((4, 0), (6, 1), (9, 2)):
        devices = paper_mix(n, seed=seed)
        cuts, lat = exhaustive_profile_optimum(devices, batch=64)
        recomputed = huscf_iteration_latency(cuts, devices, PAPER_SERVER, 64)
        assert lat == recomputed
        # and it is a coordinate-wise optimum bound for the GA to meet
        ga = optimize_cuts(devices, batch=64, config=CFG)
        assert ga.latency <= lat * 1.05


def test_fused_default_on_and_oracle_operators_agree_small():
    """Spot check at a tiny scale that both paths land on the same
    optimum (the option space is small enough that quality ties)."""
    devices = [PAPER_DEVICES[0], PAPER_DEVICES[3], PAPER_DEVICES[6]]
    cfg = GAConfig(population_size=100, generations=20, seed=0)
    host = optimize_cuts(devices, batch=64, config=cfg, fused=False)
    fused = optimize_cuts(devices, batch=64, config=cfg)   # default True
    assert np.isclose(host.latency, fused.latency, rtol=1e-6)
