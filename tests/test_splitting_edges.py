"""Regroup edge cases in core/splitting.py (churn semantics the
SplitProgram consumers rely on): profile-collision merges, empty-group
elimination, and server-union shrinkage when the last delegator of a
layer leaves.
"""
import jax
import numpy as np

from repro.core.latency import Cut, DeviceProfile, PAPER_DEVICES
from repro.core.segments import compile_split_program
from repro.core.splitting import (bucket_size, group_by_profile,
                                  server_union_span)

D1, D2, D3 = PAPER_DEVICES[0], PAPER_DEVICES[1], PAPER_DEVICES[2]


def test_group_merge_on_profile_collision():
    """Clients with the same (profile, cut) merge into ONE group even
    when interleaved with others; global order is preserved inside the
    group and group names sort deterministically."""
    devices = [D1, D2, D1, D3, D1, D2]
    cuts = [Cut(1, 4, 1, 4), Cut(2, 3, 2, 3), Cut(1, 4, 1, 4),
            Cut(1, 3, 1, 3), Cut(1, 4, 1, 4), Cut(2, 3, 2, 3)]
    groups = group_by_profile(devices, cuts)
    assert len(groups) == 3
    by_name = {g.name: g for g in groups}
    assert by_name[f"device1|{(1, 4, 1, 4)}"].client_ids == [0, 2, 4]
    assert by_name[f"device2|{(2, 3, 2, 3)}"].client_ids == [1, 5]
    assert by_name[f"device3|{(1, 3, 1, 3)}"].client_ids == [3]
    assert [g.name for g in groups] == sorted(g.name for g in groups)


def test_same_device_different_cut_does_not_merge():
    """The merge key is (profile, cut) — one device class re-cut two
    ways stays two groups (their client segments have different
    owned-layer sets and cannot stack)."""
    devices = [D1, D1]
    cuts = [Cut(1, 4, 1, 4), Cut(2, 3, 2, 3)]
    groups = group_by_profile(devices, cuts)
    assert len(groups) == 2
    assert {g.size for g in groups} == {1}


def test_empty_group_elimination_on_churn():
    """Regrouping after every member of a group leaves produces no
    empty group — and the compiled program loses that cut's join
    barriers entirely."""
    devices = [D1, D1, D2, D3]
    cuts = [Cut(1, 4, 1, 4)] * 2 + [Cut(2, 3, 2, 3), Cut(2, 4, 2, 4)]
    before = group_by_profile(devices, cuts)
    assert len(before) == 3
    # both device1 clients leave
    after = group_by_profile(devices[2:], cuts[2:])
    assert len(after) == 2
    assert all(g.size > 0 for g in after)
    assert not any(g.name.startswith("device1") for g in after)
    prog = compile_split_program(after, "G")
    joins = [g for s in prog.steps for g in s.joins]
    assert sorted(joins) == sorted(g.name for g in after)
    # ids re-enumerate over the surviving population (the trainer owns
    # any global-id remapping; groups are positional)
    assert sorted(cid for g in after for cid in g.client_ids) == [0, 1]


def test_server_union_shrinks_when_last_delegator_leaves():
    """Only the device1 group delegates layer 3; once it is gone the
    union span (and the compiled server trunk) shrinks."""
    devices = [D1, D2, D3]
    cuts = [Cut(1, 4, 1, 4), Cut(2, 3, 2, 3), Cut(2, 3, 2, 3)]
    groups = group_by_profile(devices, cuts)
    assert server_union_span(groups, "G", 5) == [1, 2, 3]
    shrunk = group_by_profile(devices[1:], cuts[1:])
    assert server_union_span(shrunk, "G", 5) == [2]
    prog = compile_split_program(shrunk, "G")
    assert prog.server_span() == (2,)
    # the single remaining layer both joins and departs every group
    (step,) = prog.steps
    assert step.joins == step.departs == prog.group_names


def test_server_union_grows_on_join():
    """A joiner with a wider cut extends the span — layers no incumbent
    delegates appear in the compiled trunk."""
    devices = [D2, D3]
    cuts = [Cut(2, 3, 2, 3), Cut(2, 3, 2, 3)]
    assert server_union_span(group_by_profile(devices, cuts), "G", 5) == [2]
    grown = group_by_profile(devices + [D1], cuts + [Cut(1, 4, 1, 4)])
    assert server_union_span(grown, "G", 5) == [1, 2, 3]


def test_bucket_size_boundaries():
    assert [bucket_size(n) for n in (0, 1, 2, 3, 4, 5, 8, 9, 1023)] == \
        [1, 1, 2, 4, 4, 8, 8, 16, 1024]
    np.testing.assert_raises(ValueError, bucket_size, -1)
    # idempotent on its own outputs (buckets are stable keys)
    for n in (1, 2, 4, 64):
        assert bucket_size(bucket_size(n)) == bucket_size(n)
