"""Per-architecture smoke tests (deliverable f): a REDUCED variant of
each assigned family runs one forward/train step on CPU, asserting
output shapes and finiteness. The FULL configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models import transformer as T
from repro.optim import adam

ARCHS = list_archs()


def _smoke_batch(cfg, B=2, S=24, rng=None):
    rng = rng or np.random.default_rng(0)
    if cfg.is_encoder_decoder:
        S_dec = 12
        return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S_dec)),
                                      dtype=jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S_dec)),
                                      dtype=jnp.int32),
                "enc_frames": jnp.asarray(
                    rng.normal(0, 1, (B, cfg.num_prefix_embeds, cfg.d_model)),
                    dtype=jnp.float32)}
    if cfg.frontend == "vision":
        P = cfg.num_prefix_embeds
        return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S - P)),
                                      dtype=jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S - P)),
                                      dtype=jnp.int32),
                "prefix_embeds": jnp.asarray(
                    rng.normal(0, 1, (B, P, cfg.d_model)), dtype=jnp.float32)}
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  dtype=jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  dtype=jnp.int32)}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_config_is_reduced(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 3 and cfg.d_model <= 512
    assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The registered full config must carry the exact assigned numbers."""
    expected = {
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected
    assert cfg.citation


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_no_nans(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = T.init_lm(key, cfg)
    batch = _smoke_batch(cfg)
    train_step, opt_init = T.make_train_step(cfg, adam(1e-3))
    opt_state = opt_init(params)
    step = jax.jit(train_step)
    params2, opt_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    l0 = jax.tree_util.tree_leaves(params)[0]
    l1 = jax.tree_util.tree_leaves(params2)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))
    # loss decreases over a few steps on a repeated batch
    for _ in range(3):
        params2, opt_state, m2 = step(params2, opt_state, batch)
    assert float(m2["loss"]) < float(metrics["loss"])


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_output_shape(arch):
    cfg = get_smoke_config(arch)
    params = T.init_lm(jax.random.PRNGKey(1), cfg)
    batch = _smoke_batch(cfg)
    logits, aux = T.forward_train(cfg, params, batch["tokens"],
                                  prefix_embeds=batch.get("prefix_embeds"),
                                  enc_frames=batch.get("enc_frames"))
    B = batch["tokens"].shape[0]
    S_text = batch["tokens"].shape[1]
    P = batch.get("prefix_embeds").shape[1] if "prefix_embeds" in batch else 0
    assert logits.shape == (B, S_text + P, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
