"""SplitProgram (core/segments.py): the one compiled representation of
a cut configuration shared by training, the latency model, and serving.

The acceptance bar for the refactor: the new executor is BIT-EXACT
against the legacy `build_net_apply_legacy` loops (kept as the oracle
behind `HuSCFConfig.split_program=False`), and the program-structure
analytic latency is exactly the host Eq. 7-10 model.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.huscf import (HuSCFConfig, HuSCFTrainer, build_net_apply,
                              build_net_apply_legacy)
from repro.core.latency import (Cut, DeviceProfile, PAPER_DEVICES,
                                PAPER_SERVER, huscf_iteration_latency)
from repro.core.segments import (compile_split_program, join_barrier_scan,
                                 make_apply, program_iteration_latency,
                                 program_net_latency)
from repro.core.splitting import group_by_profile
from repro.models.gan import Z_DIM

from test_recut import GA, mk_clients

CUTS = [Cut(1, 4, 1, 4), Cut(2, 3, 2, 3), Cut(1, 3, 2, 4)]
DEVS = [PAPER_DEVICES[0], PAPER_DEVICES[1], PAPER_DEVICES[2]]


def _mk_groups(sizes=(2, 3, 1)):
    devices, cuts = [], []
    for dev, cut, n in zip(DEVS, CUTS, sizes):
        devices += [dev] * n
        cuts += [cut] * n
    return group_by_profile(devices, cuts), devices, cuts


def _init_state(groups, net, key):
    from repro.launch.serve_split import init_gan_serving_state
    return init_gan_serving_state(key, groups, net=net)


def _mk_inputs(groups, net, batch, seed=0):
    rng = np.random.default_rng(seed)
    inputs = {}
    for g in groups:
        y = jnp.asarray(rng.integers(0, 10, (g.size, batch)), jnp.int32)
        if net == "G":
            z = jnp.asarray(rng.normal(0, 1, (g.size, batch, Z_DIM)),
                            jnp.float32)
            inputs[g.name] = (z, y)
        else:
            img = jnp.asarray(rng.normal(0, 1, (g.size, batch, 28, 28, 1)),
                              jnp.float32)
            inputs[g.name] = (img, y)
    return inputs


# ---------------------------------------------------------------------------
# program structure
# ---------------------------------------------------------------------------

def test_program_structure():
    groups, _, _ = _mk_groups()
    prog = compile_split_program(groups, "G")
    assert prog.net == "G" and prog.n_layers == 5 and prog.middle == 2
    assert prog.group_names == tuple(g.name for g in groups)
    # server span is the union of every present cut's server layers
    assert prog.server_span() == (1, 2, 3)
    by_layer = {s.layer: s for s in prog.steps}
    for g in groups:
        h, t = g.cut.g_h, g.cut.g_t
        assert g.name in by_layer[h].joins
        assert g.name in by_layer[t - 1].departs
        for l in range(1, 4):
            assert (g.name in by_layer[l].active) == (h <= l < t)
    # every group's middle layer runs on the server
    assert all(prog.middle in range(h, t) for h, t in prog.cuts)
    # heads/tails cover exactly the client-owned layers
    for seg, (h, _) in zip(prog.heads, prog.cuts):
        assert (seg.start, seg.stop) == (0, h)
    for seg, (_, t) in zip(prog.tails, prog.cuts):
        assert (seg.start, seg.stop) == (t, 5)


def test_program_shape_key_buckets():
    groups, _, _ = _mk_groups(sizes=(2, 3, 1))
    prog = compile_split_program(groups, "D")
    assert prog.sizes == (2, 3, 1)
    assert prog.buckets == (2, 4, 1)
    # padded shape keys collapse any in-bucket size to one compile key
    groups2, _, _ = _mk_groups(sizes=(2, 4, 1))
    prog2 = compile_split_program(groups2, "D")
    assert prog.shape_key() != prog2.shape_key()
    assert prog.shape_key(padded=True) == prog2.shape_key(padded=True)


# ---------------------------------------------------------------------------
# executor bit-exactness vs the legacy oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("net", ["G", "D"])
@pytest.mark.parametrize("concat_groups", [True, False])
def test_make_apply_bitexact_vs_legacy(net, concat_groups):
    groups, _, _ = _mk_groups()
    client, server = _init_state(groups, net, jax.random.PRNGKey(1))
    inputs = _mk_inputs(groups, net, batch=4)
    new = jax.jit(build_net_apply(groups, net, capture_middle=True,
                                  concat_groups=concat_groups),
                  static_argnums=(3,))
    old = jax.jit(build_net_apply_legacy(groups, net, capture_middle=True,
                                         concat_groups=concat_groups),
                  static_argnums=(3,))
    for train in (True, False):
        got = new(client, server, inputs, train)
        want = old(client, server, inputs, train)
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(want)):
            assert np.array_equal(np.asarray(a), np.asarray(b))


def test_trainer_split_program_flag_bitexact():
    """One full training step + federation under split_program=True is
    bit-identical to the legacy oracle path (False)."""
    states = {}
    for flag in (True, False):
        cfg = HuSCFConfig(batch=8, federate_every=1, seed=0,
                          steps_per_epoch=1, warmup_fed_rounds=0,
                          split_program=flag)
        clients = mk_clients(4)
        devices = [PAPER_DEVICES[i % 2] for i in range(4)]
        tr = HuSCFTrainer(clients, devices, config=cfg, ga_config=GA)
        tr.train_steps(1)
        tr.federate()
        states[flag] = jax.tree_util.tree_leaves(
            {"G": tr.state["G"], "D": tr.state["D"]})
    assert len(states[True]) == len(states[False])
    for a, b in zip(states[True], states[False]):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Eq. 7/8 schedule machinery
# ---------------------------------------------------------------------------

def test_join_barrier_scan_matches_host_recurrence():
    rng = np.random.default_rng(0)
    terms = rng.uniform(0, 1, 7).astype(np.float32)
    barriers = rng.uniform(0, 2, 7).astype(np.float32)
    got = np.asarray(join_barrier_scan(jnp.asarray(terms),
                                       jnp.asarray(barriers)))
    s, want = 0.0, []
    for a, bar in zip(terms, barriers):
        s = max(s + a, bar)
        want.append(s)
    np.testing.assert_allclose(got, np.asarray(want, np.float32), rtol=1e-6)
    # reverse sweep (Eq. 8): same recurrence from the top layer down
    got_r = np.asarray(join_barrier_scan(jnp.asarray(terms),
                                         jnp.asarray(barriers),
                                         reverse=True))
    s, want_r = 0.0, []
    for a, bar in zip(terms[::-1], barriers[::-1]):
        s = max(s + a, bar)
        want_r.append(s)
    np.testing.assert_allclose(got_r, np.asarray(want_r[::-1], np.float32),
                               rtol=1e-6)


def test_program_latency_equals_host_model():
    """program_iteration_latency from the compiled programs == the
    member-expanded host Eq. 7-10 model, exactly."""
    groups, devices, cuts = _mk_groups()
    prog_g = compile_split_program(groups, "G")
    prog_d = compile_split_program(groups, "D")
    profiles = {g.name: g.profile for g in groups}
    got = program_iteration_latency(prog_g, prog_d, profiles,
                                    PAPER_SERVER, batch=64)
    want = huscf_iteration_latency(cuts, devices, PAPER_SERVER, batch=64)
    assert math.isclose(got, want, rel_tol=1e-12)


def test_program_latency_counts_override():
    """counts= rebills the schedule for a serving cohort: more requests
    on a cut monotonically raises the forward latency."""
    groups, _, _ = _mk_groups()
    prog = compile_split_program(groups, "G")
    profiles = {g.name: g.profile for g in groups}
    base = {g.name: 1.0 for g in groups}
    lo, _ = program_net_latency(prog, profiles, batch=1, counts=base)
    hi, _ = program_net_latency(
        prog, profiles, batch=1,
        counts={g: 4.0 * c for g, c in base.items()})
    assert hi > lo > 0.0
