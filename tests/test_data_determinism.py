"""Cross-process reproducibility of the procedural datasets.

Regression for a real flake: ``make_dataset`` salted its RNG with
``hash(domain)``, and Python randomizes str hashing per process
(PYTHONHASHSEED), so ``build_scenario(seed=0)`` produced different data
— and therefore different trained states, cluster counts, and
federation weights — in every pytest invocation. The knife-edge
tolerance in test_system.py::test_federation_diagnostics failed on
roughly the unlucky tail of that lottery. The salt is now a stable
``zlib.crc32``.
"""
import hashlib
import os
import subprocess
import sys

import numpy as np

from repro.data import DOMAINS, make_dataset

_CHILD = """
import hashlib, sys
import numpy as np
from repro.data import make_dataset
imgs, labs = make_dataset(sys.argv[1], 32, seed=3)
h = hashlib.md5(imgs.tobytes() + labs.tobytes()).hexdigest()
print(h, end="")
"""


def _dataset_md5(domain):
    imgs, labs = make_dataset(domain, 32, seed=3)
    return hashlib.md5(imgs.tobytes() + labs.tobytes()).hexdigest()


def test_make_dataset_stable_across_hash_seeds():
    domain = DOMAINS[0]
    want = _dataset_md5(domain)
    for hash_seed in ("101", "202"):
        env = dict(os.environ, PYTHONHASHSEED=hash_seed)
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in sys.path if p) + os.pathsep + env.get("PYTHONPATH", "")
        got = subprocess.run([sys.executable, "-c", _CHILD, domain],
                             capture_output=True, text=True, env=env,
                             check=True).stdout
        assert got == want, f"PYTHONHASHSEED={hash_seed} changed the data"


def test_domains_get_distinct_salts():
    # the crc32 salt must keep domains decorrelated at equal seed
    hashes = {_dataset_md5(d) for d in DOMAINS}
    assert len(hashes) == len(DOMAINS)
