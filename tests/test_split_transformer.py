"""HuSCF applied to transformers (§7.3): split forward equivalence,
training progress, and clustered federation semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.split_transformer import (LMProfileGroup, default_groups,
                                          federate_split_lm, init_split_lm,
                                          make_split_train_step,
                                          split_lm_forward)
from repro.data.tokens import lm_batches


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"), n_layers=6)
    groups = default_groups(cfg, n_weak=2, n_strong=2)
    params = init_split_lm(jax.random.PRNGKey(0), cfg, groups)
    return cfg, groups, params


def _batch(cfg, groups, seed=0, b=2, s=16):
    rng = np.random.default_rng(seed)
    return {
        "tokens": {g.name: jnp.asarray(
            rng.integers(0, cfg.vocab, (g.n_clients, b, s)), jnp.int32)
            for g in groups},
        "labels": {g.name: jnp.asarray(
            rng.integers(0, cfg.vocab, (g.n_clients, b, s)), jnp.int32)
            for g in groups},
    }


def test_forward_shapes_and_finiteness(setup):
    cfg, groups, params = setup
    batch = _batch(cfg, groups)
    logits = split_lm_forward(cfg, params, groups, batch["tokens"])
    for g in groups:
        assert logits[g.name].shape == (g.n_clients, 2, 16, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits[g.name])))


def test_clients_isolated_within_group(setup):
    """Per-client segments: changing one client's head must not change
    another client's logits (data/label isolation per paper)."""
    cfg, groups, params = setup
    batch = _batch(cfg, groups)
    base = split_lm_forward(cfg, params, groups, batch["tokens"])
    g0 = groups[0]
    perturbed = jax.tree_util.tree_map(lambda x: x, params)
    emb = perturbed["clients"][g0.name]["embed"]["table"]
    perturbed["clients"][g0.name]["embed"] = {
        "table": emb.at[0].add(1.0)}  # client 0 only
    out = split_lm_forward(cfg, perturbed, groups, batch["tokens"])
    # client 0 changed
    assert not np.allclose(np.asarray(out[g0.name][0]),
                           np.asarray(base[g0.name][0]))
    # client 1 untouched
    np.testing.assert_allclose(np.asarray(out[g0.name][1]),
                               np.asarray(base[g0.name][1]), atol=1e-6)


def test_training_reduces_loss(setup):
    cfg, groups, params = setup
    step, opt_init = make_split_train_step(cfg, groups, lr=3e-4)
    opt = opt_init(params)
    step = jax.jit(step)
    batch = _batch(cfg, groups, seed=1)
    p, o, m0 = step(params, opt, batch)
    for _ in range(5):
        p, o, m = step(p, o, batch)
    assert float(m["loss"]) < float(m0["loss"])


def test_federation_cluster_isolation(setup):
    """Clients in different clusters must not mix embeddings."""
    cfg, groups, params = setup
    # mark clients with distinct constants
    marked = jax.tree_util.tree_map(lambda x: x, params)
    for gi, g in enumerate(groups):
        t = marked["clients"][g.name]["embed"]["table"]
        marks = jnp.arange(g.n_clients, dtype=t.dtype) + 10 * gi
        marked["clients"][g.name]["embed"]["table"] = (
            jnp.zeros_like(t) + marks[:, None, None])
    # clusters: {g0c0, g0c1} vs {g1c0, g1c1}
    labels = np.array([0, 0, 1, 1])
    weights = np.array([0.5, 0.5, 0.25, 0.75])
    out = federate_split_lm(marked, groups, weights, labels)
    g0, g1 = groups
    t0 = np.asarray(out["clients"][g0.name]["embed"]["table"])
    t1 = np.asarray(out["clients"][g1.name]["embed"]["table"])
    # cluster 0 average = (0 + 1)/2 = 0.5; both members receive it
    np.testing.assert_allclose(t0[0], 0.5, atol=1e-5)
    np.testing.assert_allclose(t0[1], 0.5, atol=1e-5)
    # cluster 1 weighted avg = 0.25*10 + 0.75*11 = 10.75
    np.testing.assert_allclose(t1[0], 10.75, atol=1e-5)
    np.testing.assert_allclose(t1[1], 10.75, atol=1e-5)


def test_cut_depths_respected(setup):
    cfg, groups, params = setup
    for g in groups:
        heads = params["clients"][g.name]["head"]
        tails = params["clients"][g.name]["tail"]
        assert len(heads) == g.cut_head
        assert len(tails) == g.cut_tail
