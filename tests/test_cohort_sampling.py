"""ClientRegistry cohort sampling + Eq.-15 cohort weight
renormalization (core/registry.py, kld.cohort_federation_weights[_jax]).

Regression surface:
  * renormalized weights sum to 1 within every non-empty
    (cluster ∩ cohort) and are exactly 0 for non-members — numpy f64
    and the traced f32 twin agree;
  * the paper's beta=150 survives in log-space: equal KLDs within a
    cohort stay size-proportional (the literal n_k exp(-beta KLD)
    underflows to all-zero there — the PR-4 guard, extended to the
    cohort mask), and an empty (cluster ∩ cohort) yields zeros, never
    NaN;
  * a singleton cohort member in a cluster degenerates to weight 1.0;
  * sampling is a seeded-PRNG permutation prefix: sorted, unique,
    in-range, deterministic per key, different across the trainer's
    key chain — and chaining keys covers the whole registry.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kld as kldm
from repro.core.registry import ClientRegistry


def _case(seed, n=12, n_clusters=3):
    rng = np.random.default_rng(seed)
    return (rng.random(n) * 3.0,                        # klds
            rng.integers(20, 500, n),                   # sizes
            rng.integers(0, n_clusters, n),             # labels
            rng.random(n) < 0.5)                        # cohort mask


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("beta", [0.0, 5.0, 150.0])
def test_cohort_weights_sum_to_one_per_cluster(seed, beta):
    klds, sizes, labels, mask = _case(seed)
    w = kldm.cohort_federation_weights(klds, sizes, labels, mask, beta=beta)
    assert np.all(w[~mask] == 0.0)
    assert np.all(w >= 0) and np.all(np.isfinite(w))
    for c in np.unique(labels):
        members = mask & (labels == c)
        if members.any():
            np.testing.assert_allclose(w[members].sum(), 1.0, rtol=1e-12)
        assert np.all(w[~mask & (labels == c)] == 0.0)


@pytest.mark.parametrize("seed", [0, 3])
def test_cohort_weights_jax_matches_numpy(seed):
    klds, sizes, labels, mask = _case(seed)
    n_clusters = int(labels.max()) + 1
    want = kldm.cohort_federation_weights(klds, sizes, labels, mask, beta=5.0)
    got = kldm.cohort_federation_weights_jax(
        jnp.asarray(klds, jnp.float32), jnp.asarray(sizes, jnp.float32),
        jnp.asarray(labels, jnp.int32), jnp.asarray(mask), n_clusters,
        beta=5.0)
    # f32 twin vs f64 oracle: beta multiplies the KLD rounding into the
    # logits — same 1e-4 bound the dense device-weight tests use
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-4)
    # and under jit with the mask traced (XLA refuses bit-exactness —
    # fusion reassociates the exp/normalize — but stays within ulps)
    jitted = jax.jit(kldm.cohort_federation_weights_jax,
                     static_argnums=(4, 5))
    got_j = jitted(jnp.asarray(klds, jnp.float32),
                   jnp.asarray(sizes, jnp.float32),
                   jnp.asarray(labels, jnp.int32), jnp.asarray(mask),
                   n_clusters, 5.0)
    np.testing.assert_allclose(np.asarray(got_j), np.asarray(got),
                               atol=1e-6, rtol=0)


def test_cohort_weights_no_underflow_at_paper_beta():
    """Equal KLDs of 8.0 at beta=150: exp(-1200) == 0.0 even in f64 —
    the log-space cohort softmax must stay size-proportional over the
    cohort instead of collapsing to uniform (or NaN)."""
    klds = np.full(6, 8.0)
    sizes = np.array([100, 300, 500, 100, 200, 400])
    labels = np.zeros(6, np.int64)
    mask = np.array([True, True, False, True, False, True])
    w = kldm.cohort_federation_weights(klds, sizes, labels, mask, beta=150.0)
    sub = sizes[mask] / sizes[mask].sum()
    np.testing.assert_allclose(w[mask], sub, rtol=1e-12)
    assert np.all(w[~mask] == 0.0)
    got = kldm.cohort_federation_weights_jax(
        jnp.asarray(klds, jnp.float32), jnp.asarray(sizes, jnp.float32),
        jnp.asarray(labels, jnp.int32), jnp.asarray(mask), 1, beta=150.0)
    # the f32 twin cancels |logits| ~ beta*KLD = 1200 in the seg-max
    # shift, leaving ~1e-4 relative in the size ratios
    np.testing.assert_allclose(np.asarray(got)[mask], sub, atol=1e-4)
    assert np.all(np.asarray(got)[~mask] == 0.0)


def test_singleton_and_empty_cohort_clusters():
    """One cohort member in a cluster -> weight exactly 1.0; a cluster
    with no cohort members -> all zeros (and no NaN from the guarded
    -inf seg-max in the traced twin)."""
    klds = np.array([0.5, 1.0, 2.0, 0.1])
    sizes = np.array([10, 20, 30, 40])
    labels = np.array([0, 0, 1, 1])
    mask = np.array([True, False, False, False])   # cluster 1 empty
    w = kldm.cohort_federation_weights(klds, sizes, labels, mask, beta=150.0)
    np.testing.assert_array_equal(w, [1.0, 0.0, 0.0, 0.0])
    got = np.asarray(kldm.cohort_federation_weights_jax(
        jnp.asarray(klds, jnp.float32), jnp.asarray(sizes, jnp.float32),
        jnp.asarray(labels, jnp.int32), jnp.asarray(mask), 2, beta=150.0))
    assert np.all(np.isfinite(got))
    np.testing.assert_array_equal(got, [1.0, 0.0, 0.0, 0.0])


def test_full_mask_reduces_to_federation_weights():
    klds, sizes, labels, _ = _case(4)
    want = kldm.federation_weights(klds, sizes, labels, beta=150.0)
    got = kldm.cohort_federation_weights(klds, sizes, labels,
                                         np.ones(len(klds), bool), beta=150.0)
    np.testing.assert_allclose(got, want, rtol=1e-12)


# --------------------------------------------------------------------------
# registry sampling
# --------------------------------------------------------------------------

def test_sample_cohort_sorted_unique_in_range_deterministic():
    reg = ClientRegistry(sizes=np.arange(1, 21) * 10)
    key = jax.random.PRNGKey(0)
    ids = np.asarray(reg.sample_cohort(key, 7))
    assert ids.shape == (7,) and ids.dtype == np.int32
    assert np.array_equal(ids, np.sort(ids))
    assert len(np.unique(ids)) == 7
    assert ids.min() >= 0 and ids.max() < 20
    # same key -> same cohort; next key in a chain -> (generically) not
    again = np.asarray(reg.sample_cohort(key, 7))
    np.testing.assert_array_equal(again, ids)
    other = np.asarray(reg.sample_cohort(jax.random.split(key)[1], 7))
    assert not np.array_equal(other, ids)
    # mask round-trips the ids
    mask = np.asarray(reg.cohort_mask(reg.sample_cohort(key, 7)))
    assert mask.sum() == 7 and np.all(np.flatnonzero(mask) == ids)


def test_sample_cohort_size_bounds():
    reg = ClientRegistry(sizes=np.full(5, 100))
    key = jax.random.PRNGKey(0)
    assert np.asarray(reg.sample_cohort(key, 5)).tolist() == [0, 1, 2, 3, 4]
    for bad in (0, 6, -1):
        with pytest.raises(ValueError, match="out of range"):
            reg.sample_cohort(key, bad)


def test_key_chain_covers_registry():
    """The trainer's split-per-round key chain visits every registered
    client: over enough rounds each id is sampled at least once (the
    registry/participation split would be pointless otherwise)."""
    reg = ClientRegistry(sizes=np.full(16, 50))
    key = jax.random.PRNGKey(42)
    seen = np.zeros(16, bool)
    for _ in range(40):
        key, sub = jax.random.split(key)
        seen[np.asarray(reg.sample_cohort(sub, 4))] = True
    assert seen.all(), f"unsampled clients after 40 rounds: " \
                       f"{np.flatnonzero(~seen)}"


def test_from_clients_reads_dataset_sizes():
    class Spec:
        def __init__(self, n):
            self.n = n
    reg = ClientRegistry.from_clients([Spec(5), Spec(9), Spec(2)])
    assert reg.n_clients == 3
    np.testing.assert_array_equal(reg.sizes, [5, 9, 2])
