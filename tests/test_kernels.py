"""Per-kernel correctness: shape/dtype sweeps, interpret-mode Pallas vs
the pure-jnp oracles in repro.kernels.ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.weighted_agg import clustered_agg_flat, weighted_agg_flat
from repro.kernels.kmeans_assign import kmeans_assign
from repro.kernels.flash_decode import flash_decode
from repro.kernels.mem_attention import mem_attention


@pytest.mark.parametrize("K", [1, 3, 16])
@pytest.mark.parametrize("D", [128, 8192, 10_001])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_weighted_agg_sweep(K, D, dtype):
    key = jax.random.PRNGKey(K * 1000 + D)
    x = jax.random.normal(key, (K, D), dtype)
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(1), (K,)))
    got = weighted_agg_flat(x, w, interpret=True)
    want = ref.weighted_agg_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_weighted_agg_nd_tree():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 3, 7, 5))
    w = jnp.asarray([0.1, 0.2, 0.3, 0.4])
    got = ops.weighted_agg(x, w)
    want = ref.weighted_agg_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("S", [1, 4, 15])
@pytest.mark.parametrize("K", [1, 3, 32])
@pytest.mark.parametrize("D", [128, 8192, 10_001])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_clustered_agg_sweep(S, K, D, dtype):
    key = jax.random.PRNGKey(S * 100 + K * 10 + D)
    x = jax.random.normal(key, (K, D), dtype)
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(2), (S, K)),
                       axis=1)
    got = clustered_agg_flat(w, x, interpret=True)
    want = ref.clustered_agg_ref(w, x)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("block_tiles", [1, 2, None])
def test_clustered_agg_block_tiles(block_tiles):
    """Tiled streaming (compiled-mode layout) and coalesced interpret
    blocks agree with the oracle."""
    S, K, D = 6, 5, 3 * 8 * 1024 + 77
    x = jax.random.normal(jax.random.PRNGKey(0), (K, D))
    w = jax.random.normal(jax.random.PRNGKey(1), (S, K))
    got = clustered_agg_flat(w, x, block_tiles=block_tiles, interpret=True)
    want = ref.clustered_agg_ref(w, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_clustered_agg_nd_op():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 3, 7, 5))
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(1), (2, 4)))
    got = ops.clustered_agg(w, x)
    want = ref.clustered_agg_ref(w, x)
    assert got.shape == (2, 3, 7, 5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_weighted_agg_is_single_segment_case():
    x = jax.random.normal(jax.random.PRNGKey(0), (5, 1000))
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(1), (5,)))
    single = weighted_agg_flat(x, w, interpret=True)
    multi = clustered_agg_flat(w.reshape(1, -1), x, interpret=True)[0]
    np.testing.assert_allclose(np.asarray(single), np.asarray(multi),
                               atol=1e-6)


@pytest.mark.parametrize("N", [1, 100, 257])
@pytest.mark.parametrize("M", [2, 6])
@pytest.mark.parametrize("D", [32, 300])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_assign_sweep(N, M, D, dtype):
    """Oracle sweep mirroring the clustered_agg shape/dtype sweeps: N
    crossing the 128-row tile (1 / sub-tile / non-multiple), D far from
    any lane multiple, both f32 and bf16 inputs (centers scaled x3 so
    assignments are decisive under bf16 rounding)."""
    key = jax.random.PRNGKey(N + M + D)
    x = jax.random.normal(key, (N, D), dtype)
    c = jax.random.normal(jax.random.PRNGKey(1), (M, D), dtype) * 3
    got = kmeans_assign(x, c, interpret=True)
    want = ref.kmeans_assign_ref(x, c)
    assert got.dtype == jnp.int32 and got.shape == (N,)
    assert bool(jnp.all(got == want))


def test_kmeans_assign_single_center():
    """M=1 degenerates to the constant assignment."""
    x = jax.random.normal(jax.random.PRNGKey(0), (70, 48))
    c = jax.random.normal(jax.random.PRNGKey(1), (1, 48))
    got = kmeans_assign(x, c, interpret=True)
    assert bool(jnp.all(got == 0))
    assert bool(jnp.all(got == ref.kmeans_assign_ref(x, c)))


def test_kmeans_assign_exact_ties_pick_lowest_index():
    """Duplicated center rows produce exact distance ties; argmin must
    resolve to the first occurrence, identically in kernel and oracle
    (the kernel drops the ||x||^2 term — ties must survive that)."""
    c_base = jax.random.normal(jax.random.PRNGKey(2), (3, 64)) * 2
    c = jnp.concatenate([c_base, c_base[::-1]], axis=0)   # rows 0..2 == 5..3
    x = c_base + 0.01 * jax.random.normal(jax.random.PRNGKey(3), (3, 64))
    got = kmeans_assign(x, c, interpret=True)
    want = ref.kmeans_assign_ref(x, c)
    assert bool(jnp.all(got == want))
    assert bool(jnp.all(got == jnp.arange(3)))   # first of each dup pair


def test_kmeans_assign_jitted_op():
    """The jitted public wrapper (ops.kmeans_assign) matches the oracle
    — the path the clustering stage actually calls."""
    x = jax.random.normal(jax.random.PRNGKey(4), (200, 96))
    c = jax.random.normal(jax.random.PRNGKey(5), (5, 96)) * 3
    got = ops.kmeans_assign(x, c)
    want = ref.kmeans_assign_ref(x, c)
    assert bool(jnp.all(got == want))


@pytest.mark.parametrize("B,H,KV,hd", [(1, 4, 4, 16), (2, 8, 2, 32),
                                       (3, 6, 1, 64)])
@pytest.mark.parametrize("S,blk", [(64, 64), (100, 32), (1000, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, H, KV, hd, S, blk, dtype):
    keys = jax.random.split(jax.random.PRNGKey(B * S), 3)
    q = jax.random.normal(keys[0], (B, H, hd), dtype)
    k = jax.random.normal(keys[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(keys[2], (B, S, KV, hd), dtype)
    clen = jnp.asarray(S - 7, jnp.int32)
    got = flash_decode(q, k, v, clen, block_s=blk, interpret=True)
    want = ref.flash_decode_ref(q, k, v, clen)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_decode_empty_prefix_masking():
    """Tokens past cache_len must not contribute."""
    key = jax.random.PRNGKey(0)
    B, H, KV, hd, S = 1, 2, 2, 8, 32
    q = jax.random.normal(key, (B, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd))
    clen = jnp.asarray(5, jnp.int32)
    got = flash_decode(q, k, v, clen, block_s=8, interpret=True)
    # corrupting the masked region must not change the result
    k2 = k.at[:, 5:].set(99.0)
    v2 = v.at[:, 5:].set(-99.0)
    got2 = flash_decode(q, k2, v2, clen, block_s=8, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(got2), atol=1e-6)


@pytest.mark.parametrize("B,H,KV,hd", [(1, 4, 4, 16), (2, 4, 2, 16),
                                       (3, 6, 3, 32)])
@pytest.mark.parametrize("S,bq,bk", [(37, 16, 16), (128, 128, 128),
                                     (300, 128, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_mem_attention_sweep(B, H, KV, hd, S, bq, bk, causal):
    keys = jax.random.split(jax.random.PRNGKey(B * S + causal), 3)
    q = jax.random.normal(keys[0], (B, S, H, hd))
    k = jax.random.normal(keys[1], (B, S, KV, hd))
    v = jax.random.normal(keys[2], (B, S, KV, hd))
    lens = jnp.asarray([S - i * 3 for i in range(B)], jnp.int32)
    got = mem_attention(q, k, v, lens, causal=causal, block_q=bq,
                        block_k=bk, interpret=True)
    want = ref.mem_attention_ref(q, k, v, lens, causal=causal)
    # rows past lens see an all-masked score row in both implementations
    # (normalization garbage); only valid rows are contractual.
    mask = (np.arange(S)[None, :] < np.asarray(lens)[:, None]
            )[:, :, None, None]
    np.testing.assert_allclose(np.where(mask, np.asarray(got), 0.0),
                               np.where(mask, np.asarray(want), 0.0),
                               atol=2e-5, rtol=2e-5)


def test_mem_attention_length_masking():
    """KV past lens must not contribute to valid query rows."""
    B, S, H, KV, hd = 2, 48, 4, 2, 16
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(keys[0], (B, S, H, hd))
    k = jax.random.normal(keys[1], (B, S, KV, hd))
    v = jax.random.normal(keys[2], (B, S, KV, hd))
    lens = jnp.asarray([30, 17], jnp.int32)
    got = mem_attention(q, k, v, lens, block_q=16, block_k=16,
                        interpret=True)
    k2 = jnp.where((jnp.arange(S) >= 17)[None, :, None, None], 55.0, k)
    v2 = jnp.where((jnp.arange(S) >= 17)[None, :, None, None], -55.0, v)
    got2 = mem_attention(q, k2, v2, lens, block_q=16, block_k=16,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(got[1, :17]),
                               np.asarray(got2[1, :17]), atol=1e-6)


def test_mem_attention_decode_consistency():
    """Causal prefill row t == flash_decode with a t+1-token cache (the
    two serving kernels agree on their overlap)."""
    B, S, H, KV, hd = 2, 24, 4, 2, 16
    keys = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(keys[0], (B, S, H, hd))
    k = jax.random.normal(keys[1], (B, S, KV, hd))
    v = jax.random.normal(keys[2], (B, S, KV, hd))
    full = mem_attention(q, k, v, jnp.asarray(S, jnp.int32),
                         block_q=8, block_k=8, interpret=True)
    for t in (0, 7, S - 1):
        dec = flash_decode(q[:, t], k, v, jnp.asarray(t + 1, jnp.int32),
                           block_s=8, interpret=True)
        np.testing.assert_allclose(np.asarray(full[:, t]), np.asarray(dec),
                                   atol=2e-5, rtol=2e-5)


def test_mem_attention_jitted_op():
    B, S, H, KV, hd = 1, 40, 4, 4, 8
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(keys[0], (B, S, H, hd))
    k = jax.random.normal(keys[1], (B, S, KV, hd))
    v = jax.random.normal(keys[2], (B, S, KV, hd))
    got = ops.mem_attention(q, k, v, jnp.asarray(S, jnp.int32))
    want = ref.mem_attention_ref(q, k, v, jnp.asarray(S, jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
