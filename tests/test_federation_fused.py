"""Fused single-dispatch federation round vs the legacy quadruple-loop
oracle: equivalence over heterogeneous cuts and >=3 clusters, the
zero-weight-sum fallback, fedavg as the degenerate single-cluster
case, and plan caching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import federation as fed
from repro.core.federation import (FederationPlan, federate_client_params,
                                   fedavg_uniform, get_federation_plan)
from repro.core.latency import Cut, PAPER_DEVICES
from repro.core.splitting import (client_owned_layers, group_by_profile,
                                  layer_pair)
from repro.models.gan import DISC_LAYER_DEFS, GEN_LAYER_DEFS

N_LAYERS = {"G": 5, "D": 5}
# heterogeneous cuts -> 4 profile groups with distinct owned-layer sets
HET_CUTS = (Cut(1, 3, 1, 3), Cut(2, 4, 2, 4), Cut(1, 4, 2, 3),
            Cut(2, 3, 1, 4))


def build_population(n_clients, n_profiles, seed=0):
    devices = [PAPER_DEVICES[i % n_profiles] for i in range(n_clients)]
    cuts = [HET_CUTS[i % n_profiles] for i in range(n_clients)]
    groups = group_by_profile(devices, cuts)
    key = jax.random.PRNGKey(seed)
    params = {}
    for net, defs in (("G", GEN_LAYER_DEFS), ("D", DISC_LAYER_DEFS)):
        for g in groups:
            params.setdefault(g.name, {}).setdefault(net, {})
            for l in client_owned_layers(layer_pair(g.cut, net), 5):
                key, sub = jax.random.split(key)
                params[g.name][net][str(l)] = jax.vmap(
                    lambda kk, l=l: defs[l][0](kk, jnp.float32))(
                        jax.random.split(sub, g.size))
    return groups, params


def assert_trees_close(got, want, atol=1e-5):
    gl, gt = jax.tree_util.tree_flatten(got)
    wl, wt = jax.tree_util.tree_flatten(want)
    assert gt == wt
    for g, w in zip(gl, wl):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32), atol=atol)


@pytest.fixture(scope="module")
def population():
    return build_population(n_clients=9, n_profiles=3)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_fused_matches_legacy_heterogeneous(population, use_kernel):
    groups, params = population
    rng = np.random.default_rng(1)
    K = sum(g.size for g in groups)
    labels = np.arange(K) % 3          # >= 3 clusters
    weights = rng.random(K)
    want = federate_client_params(groups, params, weights, labels,
                                  n_layers=N_LAYERS, fused=False)
    got = federate_client_params(groups, params, weights, labels,
                                 n_layers=N_LAYERS, use_kernel=use_kernel)
    assert_trees_close(got, want)


def test_fused_zero_weight_sum_fallback(population):
    """A cluster whose Eq.-15 weights sum to zero falls back to the
    uniform average — identically on both paths."""
    groups, params = population
    K = sum(g.size for g in groups)
    labels = np.arange(K) % 3
    weights = np.random.default_rng(2).random(K)
    weights[labels == 1] = 0.0
    want = federate_client_params(groups, params, weights, labels,
                                  n_layers=N_LAYERS, fused=False)
    got = federate_client_params(groups, params, weights, labels,
                                 n_layers=N_LAYERS)
    assert_trees_close(got, want)


def test_fedavg_uniform_is_single_cluster_case(population):
    groups, params = population
    K = sum(g.size for g in groups)
    sizes = np.random.default_rng(3).integers(10, 100, K)
    want = fedavg_uniform(groups, params, sizes, n_layers=N_LAYERS,
                          fused=False)
    got = fedavg_uniform(groups, params, sizes, n_layers=N_LAYERS)
    assert_trees_close(got, want)
    # degenerate = federate with one global cluster + size weights
    via_federate = federate_client_params(
        groups, params, sizes / sizes.sum(), np.zeros(K, np.int64),
        n_layers=N_LAYERS)
    assert_trees_close(got, via_federate, atol=0)


def test_aggregate_preserves_copies_within_cluster(population):
    """After a round every member of a (layer, cluster) block holds the
    same aggregated copy."""
    groups, params = population
    K = sum(g.size for g in groups)
    labels = np.arange(K) % 2
    weights = np.ones(K)
    out = federate_client_params(groups, params, weights, labels,
                                 n_layers={"G": 5})
    cid_of = {g.name: g.client_ids for g in groups}
    seen = {}
    for g in groups:
        for l, tree in out[g.name]["G"].items():
            leaves = jax.tree_util.tree_leaves(tree)
            for pos, cid in enumerate(cid_of[g.name]):
                key = (l, labels[cid])
                sig = np.asarray(leaves[0][pos]).ravel()[:8].copy()
                if key in seen:
                    np.testing.assert_allclose(sig, seen[key], atol=1e-6)
                else:
                    seen[key] = sig


def test_plan_cache_reuse_and_layout(population):
    groups, params = population
    cache = {}
    tmpl = {g.name: params[g.name]["G"] for g in groups}
    p1 = get_federation_plan(groups, "G", 5, tmpl, plan_cache=cache)
    p2 = get_federation_plan(groups, "G", 5, tmpl, plan_cache=cache)
    assert p1 is p2 and len(cache) == 1
    assert p1.n_rows == sum(g.size for g in groups)
    # every (group, layer) ownership gets exactly one entry
    n_entries = sum(
        len(client_owned_layers(layer_pair(g.cut, "G"), 5)) for g in groups)
    assert len(p1.entries) == n_entries
    assert p1.n_copies == sum(g.size * len(client_owned_layers(
        layer_pair(g.cut, "G"), 5)) for g in groups)
    # flat width = union of ownable layer widths, layer runs disjoint
    runs = sorted(p1._col_runs.values())
    assert runs[0][0] == 0
    for (c0, w), (c1, _) in zip(runs, runs[1:]):
        assert c0 + w == c1
    assert p1.n_cols == runs[-1][0] + runs[-1][1]


def test_weight_segments_block_structure(population):
    """A rows are normalized over each (layer, cluster) owner block and
    zero elsewhere; seg_ids only reference real segments."""
    groups, params = population
    K = sum(g.size for g in groups)
    labels = np.arange(K) % 3
    weights = np.random.default_rng(4).random(K)
    tmpl = {g.name: params[g.name]["G"] for g in groups}
    plan = FederationPlan(groups, "G", 5, tmpl)
    A, seg_ids = plan.weight_segments(weights, labels)
    assert A.shape[0] % fed._SEGMENT_PAD == 0
    n_real = int(seg_ids.max()) + 1
    np.testing.assert_allclose(A[:n_real].sum(1), 1.0, atol=1e-6)
    assert np.all(A[n_real:] == 0)
    assert A.shape[1] == plan.n_rows and len(seg_ids) == plan.n_copies
