"""Model substrate correctness: attention vs naive, recurrent seq==step,
MoE routing invariants, GAN shapes/params."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gan, nn
from repro.models import recurrent as R
from repro.models.attention import (KVCache, chunked_attention,
                                    decode_attention)
from repro.models.moe import moe_apply, moe_init


def naive_attention(q, k, v, window=None, causal=True):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    Skv = k.shape[1]
    qh = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qh, k) / np.sqrt(hd)
    if causal:
        qpos = jnp.arange(S)[:, None]
        kpos = jnp.arange(Skv)[None, :]
        m = qpos >= kpos
        if window is not None:
            m &= (qpos - kpos) < window
        s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bkgqs,bskh->bqkgh", p, v).reshape(B, S, H, hd)


@pytest.mark.parametrize("S,qc,kc", [(16, 16, 16), (37, 8, 16), (64, 16, 8)])
@pytest.mark.parametrize("window", [None, 8])
def test_chunked_attention_matches_naive(S, qc, kc, window):
    key = jax.random.PRNGKey(S)
    B, H, KV, hd = 2, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd))
    got = chunked_attention(q, k, v, window=window, q_chunk=qc, k_chunk=kc)
    want = naive_attention(q, k, v, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_chunked_attention_grad_finite():
    key = jax.random.PRNGKey(0)
    B, S, H, KV, hd = 1, 32, 2, 2, 8
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd))
    g = jax.grad(lambda q: chunked_attention(q, k, v, q_chunk=8,
                                             k_chunk=8).sum())(q)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_cross_attention_different_lengths():
    key = jax.random.PRNGKey(0)
    B, Sq, Sk, H, KV, hd = 2, 9, 21, 4, 2, 16
    q = jax.random.normal(key, (B, Sq, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Sk, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Sk, KV, hd))
    got = chunked_attention(q, k, v, causal=False, q_chunk=4, k_chunk=8)
    want = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("block", ["rglru", "mlstm", "slstm"])
def test_recurrent_seq_equals_step(block):
    key = jax.random.PRNGKey(0)
    B, S, D = 2, 9, 12
    x = jax.random.normal(key, (B, S, D))
    if block == "rglru":
        p = R.rglru_init(key, D, 16)
        out, _ = R.rglru_seq(p, x)
        st = jnp.zeros((B, 16), jnp.float32)
        outs = []
        for t in range(S):
            o, st = R.rglru_step(p, x[:, t:t + 1], st)
            outs.append(o)
    elif block == "mlstm":
        p = R.mlstm_init(key, D, 2, 8)
        out, _ = R.mlstm_seq(p, x)
        st = {"C": jnp.zeros((B, 2, 8, 8)), "n": jnp.zeros((B, 2, 8))}
        outs = []
        for t in range(S):
            o, st = R.mlstm_step(p, x[:, t:t + 1], st)
            outs.append(o)
    else:
        p = R.slstm_init(key, D, 16)
        out, _ = R.slstm_seq(p, x)
        st = {"c": jnp.zeros((B, 16)), "n": jnp.zeros((B, 16)),
              "m": jnp.full((B, 16), -1e30)}
        outs = []
        for t in range(S):
            o, st = R.slstm_step(p, x[:, t:t + 1], st)
            outs.append(o)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.concatenate(outs, 1)),
                               atol=2e-5)


def test_rglru_state_decays():
    """|a| < 1: with zero input the state must contract."""
    key = jax.random.PRNGKey(0)
    p = R.rglru_init(key, 8, 8)
    st = jnp.ones((1, 8)) * 5.0
    x = jnp.zeros((1, 1, 8))
    _, st2 = R.rglru_step(p, x, st)
    assert float(jnp.abs(st2).max()) < 5.0


def test_moe_routing_invariants():
    key = jax.random.PRNGKey(0)
    D, F, E, k = 16, 32, 4, 2
    p = moe_init(key, D, F, E)
    x = jax.random.normal(key, (2, 8, D))
    out, aux = moe_apply(p, x, top_k=k, capacity_factor=4.0)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    # with huge capacity, every token reaches k experts
    assert float(aux["expert_counts"].sum()) == 2 * 8 * k
    assert float(aux["load_balance"]) >= 1.0 - 1e-6  # >= 1 by Cauchy-Schwarz


def test_moe_capacity_drops_tokens():
    key = jax.random.PRNGKey(0)
    p = moe_init(key, 8, 16, 2)
    x = jax.random.normal(key, (1, 16, 8))
    _, aux_small = moe_apply(p, x, top_k=1, capacity_factor=0.25)
    # capacity = 0.25*16/2 = 2 per expert -> at most 4 routed
    assert float(aux_small["expert_counts"].sum()) == 16  # counts pre-drop


def test_gan_paper_parameter_count():
    key = jax.random.PRNGKey(0)
    G = gan.init_generator(key)
    D = gan.init_discriminator(key)
    total = nn.tree_size(G) + nn.tree_size(D)
    assert 2.8e6 < total < 3.3e6  # paper: "3M parameters"


def test_gan_shapes_and_range():
    key = jax.random.PRNGKey(0)
    G = gan.init_generator(key)
    D = gan.init_discriminator(key)
    z = jax.random.normal(key, (3, gan.Z_DIM))
    y = jnp.asarray([0, 5, 9])
    img, _ = gan.generator_forward(G, z, y, train=True)
    assert img.shape == (3, 28, 28, 1)
    assert float(img.min()) >= -1.0 and float(img.max()) <= 1.0
    logits, _ = gan.discriminator_forward(D, img, y, train=True)
    assert logits.shape == (3,)


def test_kvcache_ring_append():
    c = KVCache.zeros(1, 4, 1, 2, dtype=jnp.float32)
    for t in range(6):
        kv = jnp.full((1, 1, 1, 2), float(t))
        c = c.append(kv, kv)
    # ring: slots hold tokens 4,5,2,3
    assert int(c.length) == 6
    got = np.asarray(c.k[0, :, 0, 0])
    np.testing.assert_allclose(got, [4, 5, 2, 3])
