"""Serving-path integration: prefill + decode_step must reproduce the
training forward's next-token logits for every architecture family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import transformer as T


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_train_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.n_experts:
        # capacity-based token dropping makes train-time MoE outputs
        # differ from decode; compare with undropped capacity instead
        import dataclasses
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, S = 2, 33
    kwargs = {}
    if cfg.is_encoder_decoder:
        S = 17
        kwargs["enc_frames"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.num_prefix_embeds, cfg.d_model)),
            dtype=jnp.float32)
    elif cfg.frontend == "vision":
        kwargs["prefix_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.num_prefix_embeds, cfg.d_model)),
            dtype=jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), dtype=jnp.int32)

    logits_full, _ = T.forward_train(cfg, params, toks, **kwargs)
    _, cache = T.prefill(cfg, params, toks[:, :S - 1], **kwargs)
    ld, cache2 = T.decode_step(cfg, params, toks[:, S - 1], cache)
    want = np.asarray(logits_full[:, -1], np.float32)
    got = np.asarray(ld, np.float32)
    scale = np.abs(want).max() + 1e-6
    assert np.abs(got - want).max() / scale < 5e-3
    # cache length counts prefix embeddings (VLM) as context positions
    expected_len = S + (cfg.num_prefix_embeds if cfg.frontend == "vision"
                        else 0)
    assert int(cache2["length"]) == expected_len


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "recurrentgemma-2b",
                                  "xlstm-350m"])
def test_multi_token_greedy_decode_consistency(arch):
    """Greedy decode of 4 tokens == argmax of teacher-forced forward."""
    import dataclasses
    cfg = get_smoke_config(arch)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.init_lm(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(1)
    B, S, N = 1, 16, 4
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), dtype=jnp.int32)
    logits0, cache = T.prefill(cfg, params, toks)
    # first generated token comes from the prefill logits
    cur = jnp.argmax(logits0, -1).astype(jnp.int32)
    generated = list(np.asarray(toks[0])) + [int(cur[0])]
    outs = [int(cur[0])]
    for _ in range(N - 1):
        logits, cache = T.decode_step(cfg, params, cur, cache)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(int(cur[0]))
        generated.append(int(cur[0]))
    full = jnp.asarray([generated], dtype=jnp.int32)
    logits_tf, _ = T.forward_train(cfg, params, full[:, :-1])
    if cfg.n_experts:
        # MoE: ~1e-6 routing-group numerics can flip near-tied argmaxes;
        # require the decoded token's TF logit to be within tolerance of
        # the TF max instead of exact argmax equality.
        for i, tok in enumerate(outs):
            row = np.asarray(logits_tf[0, S - 1 + i], np.float32)
            assert row.max() - row[tok] < 5e-3 * (np.abs(row).max() + 1e-6)
    else:
        tf_preds = [int(jnp.argmax(logits_tf[0, S - 1 + i]))
                    for i in range(N)]
        assert outs == tf_preds


def test_swa_cache_bounded():
    """Sliding-window archs keep O(window) cache regardless of context."""
    cfg = get_smoke_config("mixtral-8x7b")  # window 64 in smoke
    cache = T.init_cache(cfg, batch=2, ctx_len=4096)
    k = cache["scanned"]["p0_attn"]["k"]
    assert k.shape[2] <= cfg.sliding_window


def test_long_context_cache_for_ssm_is_o1():
    cfg = get_smoke_config("xlstm-350m")
    cache = T.init_cache(cfg, batch=2, ctx_len=100_000)
    total = sum(np.prod(x.shape) for x in jax.tree_util.tree_leaves(cache))
    assert total < 5e6  # constant-size state, no KV growth
