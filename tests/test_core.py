"""Properties of the paper's core machinery: latency model, GA,
clustering, KLD weighting, federation (hypothesis where natural)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (bare env)")
from hypothesis import given, settings, strategies as st

from repro.core.latency import (Cut, PAPER_DEVICES, PAPER_SERVER,
                                all_cut_options, fedgan_iteration_latency,
                                fedsplitgan_iteration_latency,
                                hflgan_iteration_latency,
                                huscf_iteration_latency,
                                mdgan_iteration_latency, valid_cuts)
from repro.core.genetic import GAConfig, optimize_cuts
from repro.core.clustering import cluster_activations, kmeans, silhouette
from repro.core import kld as kldm
from repro.core.splitting import group_by_profile
from repro.core.federation import federate_client_params


# --- latency model -----------------------------------------------------------

def test_cut_options_respect_middle_layer():
    for gh, gt in valid_cuts(5):
        assert 1 <= gh <= 2 and 3 <= gt <= 4  # middle layer 2 on server


def test_latency_positive_and_batch_monotone():
    devices = list(PAPER_DEVICES)
    cuts = [Cut(1, 3, 1, 3)] * len(devices)
    l32 = huscf_iteration_latency(cuts, devices, batch=32)
    l64 = huscf_iteration_latency(cuts, devices, batch=64)
    assert 0 < l32 < l64


def test_paper_table15_ordering():
    """Table 15: HuSCF ~ Fed-Split << MD-GAN << FedGAN < PFL < HFL."""
    devices = [PAPER_DEVICES[i % 7] for i in range(100)]
    res = optimize_cuts(devices, batch=64,
                        config=GAConfig(population_size=60, generations=15,
                                        seed=0))
    huscf = res.latency
    fed = fedgan_iteration_latency(devices, 64)
    md = mdgan_iteration_latency(devices, batch=64)
    hfl = hflgan_iteration_latency(devices, 64)
    fsg = fedsplitgan_iteration_latency(devices, batch=64)
    assert huscf < md < fed < hfl
    assert huscf < fsg * 2.5            # comparable to Fed-Split GANs
    assert fed / huscf > 5              # paper: >= 5x reduction
    # absolute scale: paper reports 7.8s (ours ~8.5 with our FLOP counts)
    assert 2.0 < huscf < 20.0


@given(st.integers(0, len(all_cut_options()) - 1),
       st.integers(2, 16))
@settings(max_examples=20, deadline=None)
def test_latency_worse_when_slower_devices(opt_idx, k):
    opts = all_cut_options()
    cuts = [opts[opt_idx]] * k
    fast = [PAPER_DEVICES[2]] * k  # device3: strongest
    slow = [PAPER_DEVICES[0]] * k  # device1: weakest
    assert huscf_iteration_latency(cuts, slow) >= \
        huscf_iteration_latency(cuts, fast)


# --- genetic algorithm -------------------------------------------------------

def test_ga_beats_naive_cuts():
    devices = [PAPER_DEVICES[i % 7] for i in range(20)]
    naive = huscf_iteration_latency([Cut(1, 3, 1, 3)] * 20, devices, batch=64)
    res = optimize_cuts(devices, batch=64,
                        config=GAConfig(population_size=50, generations=12,
                                        seed=1))
    assert res.latency <= naive


def test_ga_profile_reduction_matches_client_based():
    """Appendix D: profile-based GA reaches the same optimum, faster."""
    devices = [PAPER_DEVICES[i % 3] for i in range(12)]
    prof = optimize_cuts(devices, batch=64,
                         config=GAConfig(population_size=80, generations=20,
                                         profile_based=True, seed=0))
    client = optimize_cuts(devices, batch=64,
                           config=GAConfig(population_size=80, generations=20,
                                           profile_based=False, seed=0))
    assert prof.latency <= client.latency * 1.05


# --- clustering / KLD --------------------------------------------------------

def test_kmeans_separates_two_blobs():
    rng = np.random.default_rng(0)
    a = rng.normal(0, 0.2, (10, 8)) + 3
    b = rng.normal(0, 0.2, (12, 8)) - 3
    x = np.vstack([a, b])
    labels, centers, _ = kmeans(x, 2, seed=0)
    assert len(set(labels[:10])) == 1 and len(set(labels[10:])) == 1
    assert labels[0] != labels[-1]


def test_cluster_activation_k_selection():
    rng = np.random.default_rng(1)
    x = np.vstack([rng.normal(0, 0.3, (8, 16)) + off
                   for off in (-6, 0, 6)])
    res = cluster_activations(x, seed=0)
    assert res.k == 3


def test_cluster_single_domain_falls_back_to_one():
    """Unstructured activations: silhouette below threshold -> k=1.
    (Small-sample silhouette of pure noise sits ~0.2, hence the
    explicit threshold; the default 0.15 is tuned for the GAN's
    6272-dim mid-layer activations where noise scores lower.)"""
    rng = np.random.default_rng(2)
    x = rng.normal(0, 1.0, (12, 16))
    res = cluster_activations(x, seed=0, min_silhouette=0.3)
    assert res.k == 1
    forced = cluster_activations(x, k=2, seed=0)
    assert forced.k == 2  # explicit k always honored


@given(st.integers(2, 12), st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_federation_weights_sum_to_one_per_cluster(k_clients, n_clusters):
    rng = np.random.default_rng(k_clients * 7 + n_clusters)
    acts = rng.normal(0, 1, (k_clients, 10))
    labels = rng.integers(0, n_clusters, k_clients)
    sizes = rng.integers(50, 700, k_clients)
    w, klds = kldm.activation_weights(acts, sizes, labels)
    assert np.all(w >= 0) and np.all(np.isfinite(w))
    for c in np.unique(labels):
        np.testing.assert_allclose(w[labels == c].sum(), 1.0, atol=1e-9)
    assert np.all(klds >= -1e-9)


def test_kld_zero_for_identical_distributions():
    p = np.ones(10) / 10
    assert kldm.kl_divergence(p, p) == pytest.approx(0.0, abs=1e-12)


def test_weight_decreases_with_divergence():
    """Eq. 15: same size, higher KLD -> lower weight."""
    acts = np.array([[5.0, 0, 0, 0], [5.0, 0, 0, 0], [0, 5.0, 0, 0]])
    sizes = np.array([100, 100, 100])
    labels = np.zeros(3, np.int64)
    w, klds = kldm.activation_weights(acts, sizes, labels, beta=5.0)
    assert klds[2] > klds[0]
    assert w[2] < w[0]


def test_label_vs_activation_kld_same_interface():
    hists = np.array([[10, 0, 5], [8, 2, 5], [0, 10, 5]])
    sizes = np.array([15, 15, 15])
    labels = np.zeros(3, np.int64)
    w, _ = kldm.label_weights(hists, sizes, labels)
    np.testing.assert_allclose(w.sum(), 1.0)


# --- layer-wise clustered federation ----------------------------------------

def _tiny_population():
    from repro.core.latency import Cut, DeviceProfile
    devs = [PAPER_DEVICES[0]] * 2 + [PAPER_DEVICES[1]] * 2
    cuts = [Cut(1, 3, 1, 3)] * 2 + [Cut(2, 4, 2, 4)] * 2
    groups = group_by_profile(devs, cuts)
    return groups


def test_federation_layerwise_ownership_and_convexity():
    groups = _tiny_population()
    # client params: net G, layers per cut; leaf = scalar marker per client
    client_params = {}
    val = 0.0
    for g in groups:
        layers = {}
        owned = list(range(g.cut.g_h)) + list(range(g.cut.g_t, 5))
        for l in owned:
            layers[str(l)] = {"w": jnp.arange(g.size, dtype=jnp.float32)
                              + val}
            val += 10
        client_params[g.name] = {"G": layers}
    weights = np.full(4, 0.25)
    labels = np.zeros(4, np.int64)
    out = federate_client_params(groups, client_params, weights, labels,
                                 n_layers={"G": 5})
    # layer 0 owned by all 4 clients -> every copy equals the global mean
    vals = []
    for g in groups:
        vals.append(np.asarray(out[g.name]["G"]["0"]["w"]))
    flat_in = np.concatenate([np.asarray(client_params[g.name]["G"]["0"]["w"])
                              for g in groups])
    expected = flat_in.mean()
    for v in vals:
        np.testing.assert_allclose(v, expected, rtol=1e-6)
    # within-cluster convexity: aggregate lies in [min, max] of inputs
    assert flat_in.min() - 1e-5 <= expected <= flat_in.max() + 1e-5


def test_federation_respects_clusters():
    groups = _tiny_population()
    client_params = {}
    for gi, g in enumerate(groups):
        layers = {}
        owned = list(range(g.cut.g_h)) + list(range(g.cut.g_t, 5))
        for l in owned:
            layers[str(l)] = {"w": jnp.full((g.size, 2), float(gi))}
        client_params[g.name] = {"G": layers}
    # two clusters split along groups
    labels = np.array([0, 0, 1, 1])
    weights = np.array([0.5, 0.5, 0.5, 0.5])
    out = federate_client_params(groups, client_params, weights, labels,
                                 n_layers={"G": 5})
    g0, g1 = groups
    np.testing.assert_allclose(np.asarray(out[g0.name]["G"]["0"]["w"]), 0.0)
    np.testing.assert_allclose(np.asarray(out[g1.name]["G"]["0"]["w"]), 1.0)
