"""Split-serving benchmark: the SplitProgram engine's measured
wall-clock vs the analytic Eq. 7/9 forward prediction, per profile mix
(EXPERIMENTS.md §Split serving).

For each heterogeneous mix in ``serve_split.SERVE_MIXES`` the bench
serves one bucket-padded request cohort through the U-shaped engine
(warm, post-compile) and reports:

* ``serve/gan/<mix>/measured`` — wall-clock per cohort on this host,
  including the engine's host-side cohort staging (the thing a real
  deployment pays);
* ``serve/gan/<mix>/analytic`` — `program_forward_latency` for the
  SAME compiled program and padded multiplicities, evaluated on the
  paper's Table-4 device profiles. The derived column carries the
  measured/analytic ratio: the analytic model prices paper edge
  hardware while the measurement runs every segment on this container's
  CPU, so the ratio is NOT 1 — the claim under test is that it stays
  in one band across mixes (the schedule model and the executor move
  together; a mix-dependent ratio would mean the executor runs a
  different schedule than the model prices).

The LM rows time the U-shaped decode tail (server trunk on the Pallas
``mem_attention`` / ``flash_decode`` kernels, whole generation one
jitted scan) in tokens/s.

``tiny=True`` (ci_smoke) shrinks cohort and generation lengths; the
trajectory lands in results/bench_serve.json via ``run.py --only serve
--serve-tiny --json ...``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.serve_split import (SERVE_MIXES, ServeRequest,
                                      SplitGanEngine, SplitLMConfig,
                                      build_mix, init_gan_serving_state,
                                      init_split_lm, split_lm_generate)
from repro.models.gan import NUM_CLASSES, Z_DIM


def _mk_requests(groups, n, seed=0):
    rng = np.random.default_rng(seed)
    n_clients = sum(g.size for g in groups)
    return [ServeRequest(int(rng.integers(0, n_clients)),
                         rng.normal(0, 1, Z_DIM).astype(np.float32),
                         int(rng.integers(0, NUM_CLASSES)))
            for _ in range(n)]


def _bench_serve(engine, reqs, iters):
    engine.serve(reqs)                       # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        engine.serve(reqs)
    return (time.perf_counter() - t0) / iters


def run(report, tiny: bool = False) -> None:
    n_requests = 8 if tiny else 32
    iters = 3 if tiny else 10
    ratios = {}
    for mix in sorted(SERVE_MIXES):
        groups = build_mix(mix)
        client, server = init_gan_serving_state(jax.random.PRNGKey(0),
                                                groups)
        engine = SplitGanEngine(groups, client, server)
        reqs = _mk_requests(groups, n_requests, seed=1)
        active, buckets, _ = engine.plan(reqs)
        measured = _bench_serve(engine, reqs, iters)
        analytic = engine.predict_latency(reqs, padded=True)
        ratios[mix] = measured / analytic
        report(f"serve/gan/{mix}/measured", measured * 1e6,
               f"requests={n_requests} cuts={len(active)} "
               f"buckets={'x'.join(map(str, buckets))}")
        report(f"serve/gan/{mix}/analytic", analytic * 1e6,
               f"ratio={measured / analytic:.1f}")
    if len(ratios) > 1:
        vals = sorted(ratios.values())
        report("serve/gan/ratio_spread", vals[-1] / vals[0] * 1.0,
               "max/min measured-vs-analytic ratio across mixes "
               "(schedule-model agreement; dimensionless, not us)")

    # LM decode tail: server trunk on the Pallas kernels, one jitted scan
    batch, prompt, gen = (2, 16, 8) if tiny else (4, 64, 32)
    cfg = SplitLMConfig(s_max=prompt + gen + 16)
    params = init_split_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt)),
                         dtype=jnp.int32)
    fn = jax.jit(lambda p, t: split_lm_generate(cfg, p, t, gen))
    jax.block_until_ready(fn(params, tokens))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(params, tokens))
    per_call = (time.perf_counter() - t0) / iters
    report("serve/lm/decode_tail", per_call * 1e6,
           f"batch={batch} gen={gen} "
           f"tok_s={batch * gen / per_call:.0f} "
           f"server_blocks=[{cfg.head_end},{cfg.tail_start})")
