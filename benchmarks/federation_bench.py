"""Federation-round macro-benchmark: fused single-dispatch path vs the
legacy per-(layer, cluster, leaf) loop, plus the client-axis-sharded
round at 1/2/4/8 host devices.

32 clients x the paper cGAN (~3M params across G+D client segments),
heterogeneous cuts (4 profile groups), 3 clusters — the server-side
hot spot of every federation round (Eq. 16). Reports warm wall-clock
per round; ``bench/federation_round`` carries the headline
fused-vs-legacy comparison for the perf trajectory.

Sharded section: the forced host-device count is fixed at backend
init, so each device count runs in its own subprocess
(``python -m benchmarks.federation_bench --sharded-worker N`` under
``XLA_FLAGS=--xla_force_host_platform_device_count=N``) and reports
its warm round time back on stdout. On this CPU container the shards
share one physical socket — the numbers track dispatch/collective
overhead of the shard_map path, not real multi-host scaling.

Chunked section (DESIGN.md §Chunk-streamed aggregation): a synthetic
population-scale round — 2 profile groups over a 2-layer net with
n_cols ~ 8192 — at 1k and 8k clients, streamed in chunks of 256. The
headline is the memory claim, not wall clock: the dense paths
materialize a ``theta [K, D]`` f32 buffer (`dense_buffer_bytes`) that
grows with the client count, while the chunk stream's working set
(`chunked_buffer_bytes`) is O(chunk + clusters). Against a 128 MB
working-set envelope the 8k dense buffer (256 MB) does not fit, so its
wall clock is skipped and only the chunked round reports; at 1k both
run and the dense round is the wall-clock baseline. ``tiny=True``
(ci_smoke) keeps a 256-client / d=512 / chunk-64 variant of just this
section.
"""
from __future__ import annotations

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.kernel_bench import _bench
from repro.core.federation import federate_client_params
from repro.core.latency import Cut, PAPER_DEVICES
from repro.core.splitting import (client_owned_layers, group_by_profile,
                                  layer_pair)
from repro.models.gan import DISC_LAYER_DEFS, GEN_LAYER_DEFS

N_CLIENTS = 32
N_CLUSTERS = 3
N_LAYERS = {"G": 5, "D": 5}
_CUTS = (Cut(1, 3, 1, 3), Cut(2, 4, 2, 4), Cut(1, 4, 2, 3), Cut(2, 3, 1, 4))


def _build_population():
    devices = [PAPER_DEVICES[i % len(_CUTS)] for i in range(N_CLIENTS)]
    cuts = [_CUTS[i % len(_CUTS)] for i in range(N_CLIENTS)]
    groups = group_by_profile(devices, cuts)
    key = jax.random.PRNGKey(0)
    params = {}
    for net, defs in (("G", GEN_LAYER_DEFS), ("D", DISC_LAYER_DEFS)):
        for g in groups:
            params.setdefault(g.name, {}).setdefault(net, {})
            for l in client_owned_layers(layer_pair(g.cut, net), 5):
                key, sub = jax.random.split(key)
                keys = jax.random.split(sub, g.size)
                params[g.name][net][str(l)] = jax.vmap(
                    lambda kk, l=l: defs[l][0](kk, jnp.float32))(keys)
    # model size (one full G+D copy) for the scale label
    key = jax.random.PRNGKey(1)
    n_params = sum(
        x.size
        for defs in (GEN_LAYER_DEFS, DISC_LAYER_DEFS) for init, _ in defs
        for x in jax.tree_util.tree_leaves(init(key, jnp.float32)))
    return groups, params, n_params


def _round_inputs():
    """One source of truth for the benchmark round's inputs — the
    sharded worker subprocess must aggregate byte-identical weights/
    labels/population or its rows stop being comparable to fused_*."""
    groups, params, n_params = _build_population()
    rng = np.random.default_rng(0)
    weights = rng.random(N_CLIENTS)
    labels = np.arange(N_CLIENTS) % N_CLUSTERS
    return groups, params, n_params, weights, labels


def run(report, tiny=False):
    if tiny:
        _run_chunked(report, tiny=True)
        return
    _run_dense_vs_legacy(report)
    _run_chunked(report, tiny=False)


def _run_dense_vs_legacy(report):
    groups, params, n_params, weights, labels = _round_inputs()
    plans = {}

    def round_with(**kw):
        return federate_client_params(groups, params, weights, labels,
                                      n_layers=N_LAYERS, plan_cache=plans,
                                      **kw)

    us_fused = _bench(round_with, iters=3)
    us_kernel = _bench(lambda: round_with(use_kernel=True), iters=3)
    us_legacy = _bench(lambda: round_with(fused=False), iters=1)

    scale = f"{N_CLIENTS}c_{n_params/1e6:.1f}Mp"
    report(f"federation/fused_jnp_{scale}", us_fused, "1 jit/net")
    report(f"federation/fused_kernel_{scale}", us_kernel,
           "1 pallas_call/net (interpret)")
    report(f"federation/legacy_loop_{scale}", us_legacy,
           "per-(layer,cluster,leaf) dispatches")
    best = min(us_fused, us_kernel)
    report("bench/federation_round", best,
           f"legacy={us_legacy:.0f}us speedup={us_legacy / best:.2f}x")

    # --- sharded round at 1/2/4/8 forced host devices (subprocess per
    # count: the device-count flag binds at backend init)
    for n in SHARDED_DEVICE_COUNTS:
        us = _run_sharded_worker(n)
        derived = ("single-device fallback (mesh of 1)" if n == 1 else
                   f"shard_map+psum, {N_CLIENTS // n} client rows/shard")
        report(f"federation/sharded_round_{n}dev_{scale}", us, derived)


# ---------------------------------------------------------------------------
# chunk-streamed population-scale section
# ---------------------------------------------------------------------------

# (n_clients, chunk, f32-per-layer): 2 layers -> n_cols = 2 * d_layer
CHUNK_SCALES = ((1024, 256, 4096), (8192, 256, 4096))
CHUNK_SCALES_TINY = ((256, 64, 256),)
MEM_ENVELOPE_BYTES = 128 * 2 ** 20


def _chunk_population(n_clients, d_layer, seed=0):
    """Synthetic 2-group population over a 2-layer net: cut (1,2) owns
    layer 0 only, cut (2,2) owns both — heterogeneous ownership with
    the smallest possible layer count, so the buffers are all client
    rows, not model depth."""
    half = n_clients // 2
    devices = ([PAPER_DEVICES[0]] * half
               + [PAPER_DEVICES[1]] * (n_clients - half))
    cuts = ([Cut(1, 2, 1, 2)] * half
            + [Cut(2, 2, 2, 2)] * (n_clients - half))
    groups = group_by_profile(devices, cuts)
    rng = np.random.default_rng(seed)
    params = {}
    for g in groups:
        owned = client_owned_layers((g.cut.g_h, g.cut.g_t), 2)
        params[g.name] = {"G": {
            str(l): {"w": jnp.asarray(rng.standard_normal(
                (g.size, d_layer), dtype=np.float32))}
            for l in owned}}
    weights = rng.random(n_clients)
    labels = np.arange(n_clients) % N_CLUSTERS
    return groups, params, weights, labels


def _run_chunked(report, tiny):
    from repro.core.federation import get_federation_plan
    for n_clients, chunk, d_layer in (CHUNK_SCALES_TINY if tiny
                                      else CHUNK_SCALES):
        groups, params, weights, labels = _chunk_population(n_clients,
                                                            d_layer)
        tmpl = {g.name: params[g.name]["G"] for g in groups}
        cache = {}
        plan = get_federation_plan(groups, "G", 2, tmpl, plan_cache=cache,
                                   chunk_size=chunk)
        dense_b = plan.dense_buffer_bytes()
        work_b = plan.chunked_buffer_bytes(N_CLUSTERS)
        mem = (f"workset={work_b / 2**20:.2f}MB "
               f"dense={dense_b / 2**20:.1f}MB "
               f"ratio={dense_b / work_b:.0f}x")

        def fed(**kw):
            return federate_client_params(groups, params, weights, labels,
                                          n_layers={"G": 2},
                                          plan_cache=cache, **kw)

        us_chunk = _bench(lambda: fed(chunk_size=chunk), iters=2)
        scale = f"{n_clients}c_chunk{chunk}_d{2 * d_layer}"
        if dense_b <= MEM_ENVELOPE_BYTES:
            us_dense = _bench(lambda: fed(), iters=2)
            report(f"federation/chunked_round_{scale}", us_chunk,
                   f"{mem}; dense round {us_dense:.0f}us "
                   f"({us_chunk / us_dense:.2f}x)")
            report(f"federation/dense_round_{n_clients}c_d{2 * d_layer}",
                   us_dense, mem)
        else:
            report(f"federation/chunked_round_{scale}", us_chunk,
                   f"{mem}; dense buffer exceeds the "
                   f"{MEM_ENVELOPE_BYTES / 2**20:.0f}MB envelope -> "
                   "dense wall clock skipped")


# ---------------------------------------------------------------------------
# client-axis-sharded section
# ---------------------------------------------------------------------------

SHARDED_DEVICE_COUNTS = (1, 2, 4, 8)


def _run_sharded_worker(n_devices: int) -> float:
    from repro.launch.mesh import forced_device_env
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = forced_device_env(n_devices, [os.path.join(root, "src")])
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.federation_bench",
         "--sharded-worker", str(n_devices)],
        env=env, cwd=root, capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded worker ({n_devices} dev) failed:\n{proc.stdout}\n"
            f"{proc.stderr}")
    for line in reversed(proc.stdout.splitlines()):
        if line.startswith("SHARDED_US="):
            return float(line.split("=", 1)[1])
    raise RuntimeError(f"sharded worker emitted no SHARDED_US line:\n"
                       f"{proc.stdout}")


def _sharded_worker_main(n_devices: int) -> None:
    from repro.launch.mesh import make_federation_mesh
    assert jax.device_count() == n_devices, \
        f"worker saw {jax.device_count()} devices, wanted {n_devices}"
    groups, params, _, weights, labels = _round_inputs()
    mesh = make_federation_mesh(n_devices)
    plans = {}
    us = _bench(lambda: federate_client_params(
        groups, params, weights, labels, n_layers=N_LAYERS,
        plan_cache=plans, mesh=mesh), iters=3)
    print(f"SHARDED_US={us}", flush=True)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--sharded-worker", type=int, default=None,
                    metavar="N_DEVICES")
    args = ap.parse_args()
    if args.sharded_worker is not None:
        _sharded_worker_main(args.sharded_worker)
    else:
        run(lambda name, v, d="": print(f"{name},{v:.3f},{d}"))
