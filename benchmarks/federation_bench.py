"""Federation-round macro-benchmark: fused single-dispatch path vs the
legacy per-(layer, cluster, leaf) loop.

32 clients x the paper cGAN (~3M params across G+D client segments),
heterogeneous cuts (4 profile groups), 3 clusters — the server-side
hot spot of every federation round (Eq. 16). Reports warm wall-clock
per round; ``bench/federation_round`` carries the headline
fused-vs-legacy comparison for the perf trajectory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.kernel_bench import _bench
from repro.core.federation import federate_client_params
from repro.core.latency import Cut, PAPER_DEVICES
from repro.core.splitting import (client_owned_layers, group_by_profile,
                                  layer_pair)
from repro.models.gan import DISC_LAYER_DEFS, GEN_LAYER_DEFS

N_CLIENTS = 32
N_CLUSTERS = 3
N_LAYERS = {"G": 5, "D": 5}
_CUTS = (Cut(1, 3, 1, 3), Cut(2, 4, 2, 4), Cut(1, 4, 2, 3), Cut(2, 3, 1, 4))


def _build_population():
    devices = [PAPER_DEVICES[i % len(_CUTS)] for i in range(N_CLIENTS)]
    cuts = [_CUTS[i % len(_CUTS)] for i in range(N_CLIENTS)]
    groups = group_by_profile(devices, cuts)
    key = jax.random.PRNGKey(0)
    params = {}
    for net, defs in (("G", GEN_LAYER_DEFS), ("D", DISC_LAYER_DEFS)):
        for g in groups:
            params.setdefault(g.name, {}).setdefault(net, {})
            for l in client_owned_layers(layer_pair(g.cut, net), 5):
                key, sub = jax.random.split(key)
                keys = jax.random.split(sub, g.size)
                params[g.name][net][str(l)] = jax.vmap(
                    lambda kk, l=l: defs[l][0](kk, jnp.float32))(keys)
    # model size (one full G+D copy) for the scale label
    key = jax.random.PRNGKey(1)
    n_params = sum(
        x.size
        for defs in (GEN_LAYER_DEFS, DISC_LAYER_DEFS) for init, _ in defs
        for x in jax.tree_util.tree_leaves(init(key, jnp.float32)))
    return groups, params, n_params


def run(report):
    groups, params, n_params = _build_population()
    rng = np.random.default_rng(0)
    weights = rng.random(N_CLIENTS)
    labels = np.arange(N_CLIENTS) % N_CLUSTERS
    plans = {}

    def round_with(**kw):
        return federate_client_params(groups, params, weights, labels,
                                      n_layers=N_LAYERS, plan_cache=plans,
                                      **kw)

    us_fused = _bench(round_with, iters=3)
    us_kernel = _bench(lambda: round_with(use_kernel=True), iters=3)
    us_legacy = _bench(lambda: round_with(fused=False), iters=1)

    scale = f"{N_CLIENTS}c_{n_params/1e6:.1f}Mp"
    report(f"federation/fused_jnp_{scale}", us_fused, "1 jit/net")
    report(f"federation/fused_kernel_{scale}", us_kernel,
           "1 pallas_call/net (interpret)")
    report(f"federation/legacy_loop_{scale}", us_legacy,
           "per-(layer,cluster,leaf) dispatches")
    best = min(us_fused, us_kernel)
    report("bench/federation_round", best,
           f"legacy={us_legacy:.0f}us speedup={us_legacy / best:.2f}x")
