"""Paper Table 17 (§6.3): activation-based KLD (ours, private) vs
label-distribution KLD (FeGAN-style, leaks labels) — single-domain
non-IID. Claim: near-identical quality."""
from __future__ import annotations

import time

from repro.core import HuSCFConfig, HuSCFTrainer, PAPER_DEVICES
from repro.data import build_scenario
from benchmarks.quality_scenarios import evaluate_trainer


class _LabelKLDTrainer(HuSCFTrainer):
    def federate(self, use_label_kld: bool = True):
        return super().federate(use_label_kld=True)


def run(report, *, num_clients: int = 6, base_size: int = 96,
        epochs: int = 4, batch: int = 16):
    clients = build_scenario("1dom_noniid", num_clients=num_clients,
                             base_size=base_size, seed=0)
    devices = [PAPER_DEVICES[i % 7] for i in range(num_clients)]
    for name, cls in (("activation_kld", HuSCFTrainer),
                      ("label_kld", _LabelKLDTrainer)):
        t0 = time.time()
        tr = cls(clients, devices,
                 config=HuSCFConfig(batch=batch, federate_every=2, seed=0))
        for _ in range(epochs):
            tr.train_epoch()
        m = evaluate_trainer(tr, ["gratings"])["gratings"]
        report(f"table17/{name}", time.time() - t0,
               f"acc={m['accuracy']:.3f} f1={m['f1']:.3f} "
               f"score={m['score']:.2f}")
