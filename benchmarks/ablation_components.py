"""Paper Table 23 (appendix A): component ablation — KLD-only,
Clustering-only, both — on the two-domain highly-non-IID scenario."""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.core import HuSCFConfig, HuSCFTrainer, PAPER_DEVICES
from repro.data import build_scenario
from benchmarks.quality_scenarios import evaluate_trainer


class _NoClusterTrainer(HuSCFTrainer):
    """KLD weighting only: force a single global cluster."""

    def __init__(self, *a, **kw):
        kw.setdefault("config", HuSCFConfig())
        super().__init__(*a, **kw)
        self.cfg.num_clusters = 1


class _NoKLDTrainer(HuSCFTrainer):
    """Clustering only: uniform (size-weighted) intra-cluster weights."""

    def federate(self, use_label_kld: bool = False):
        # monkey-patch beta=0 -> exp(-0*KLD)=1 -> pure size weighting
        old = self.cfg.beta
        self.cfg.beta = 0.0
        try:
            return super().federate(use_label_kld)
        finally:
            self.cfg.beta = old


def run(report, *, num_clients: int = 6, base_size: int = 96,
        epochs: int = 4, batch: int = 16):
    clients = build_scenario("2dom_highly_noniid", num_clients=num_clients,
                             base_size=base_size, seed=0)
    devices = [PAPER_DEVICES[i % 7] for i in range(num_clients)]
    variants = {
        "kld_only": _NoClusterTrainer,
        "clustering_only": _NoKLDTrainer,
        "kld_plus_clustering": HuSCFTrainer,
    }
    for name, cls in variants.items():
        t0 = time.time()
        tr = cls(clients, devices,
                 config=HuSCFConfig(batch=batch, federate_every=2, seed=0))
        for _ in range(epochs):
            tr.train_epoch()
        metrics = evaluate_trainer(tr, ["gratings", "blobs"])
        for dom, m in metrics.items():
            report(f"table23/{name}/{dom}", time.time() - t0,
                   f"acc={m['accuracy']:.3f} score={m['score']:.2f}")
