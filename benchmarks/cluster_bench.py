"""Stage-3/4 clustered-round macro-benchmark: host-numpy path vs the
device-resident jitted path (DESIGN.md §Device-resident clustering).

One "cluster round" is everything between the trained step and the
aggregated params: middle-activation EMA -> k-means + silhouette
k-selection -> Eq. 13-15 KLD weighting -> Eq. 16 clustered aggregation.
The host path reads the [K, F] EMA back, clusters/weights in numpy,
builds the block-diagonal weight matrix on the host and re-dispatches;
the fused path runs the same chain as two dispatches (one jitted
cluster+weight call, one jitted in-jit-weight-matrix aggregation per
net) with labels/weights never leaving the device.

Population: 3 activation domains at the paper's F=6272 EMA width,
heterogeneous cuts (4 profile groups). Client segments use small dense
layers rather than the full cGAN so the 128-client round fits the CI
container — the federation_bench section already carries the
full-model aggregation numbers; this section isolates the stage-3/4
host hop. ``bench/cluster_round`` reports the headline fused-vs-numpy
speedup at the largest client count run (128, or 32 under ``tiny``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.kernel_bench import _bench
from repro.core import kld as kld_mod
from repro.core.clustering import (cluster_activations,
                                   cluster_activations_jax,
                                   k_selection_bound)
from repro.core.federation import (federate_client_params,
                                   federate_client_params_device)
from repro.core.latency import Cut, PAPER_DEVICES
from repro.core.splitting import (client_owned_layers, group_by_profile,
                                  layer_pair)

EMA_FEATURES = 6272                    # the GAN's D-middle 7x7x128 width
N_LAYERS = {"G": 5}
LAYER_SHAPE = (64, 64)                 # small dense per-layer segments
BETA = 150.0
_CUTS = (Cut(1, 3, 1, 3), Cut(2, 4, 2, 4), Cut(1, 4, 2, 3), Cut(2, 3, 1, 4))


def _build_population(n_clients: int, seed: int = 0):
    devices = [PAPER_DEVICES[i % len(_CUTS)] for i in range(n_clients)]
    cuts = [_CUTS[i % len(_CUTS)] for i in range(n_clients)]
    groups = group_by_profile(devices, cuts)
    key = jax.random.PRNGKey(seed)
    params = {}
    for g in groups:
        params[g.name] = {"G": {}}
        for l in client_owned_layers(layer_pair(g.cut, "G"), 5):
            key, sub = jax.random.split(key)
            params[g.name]["G"][str(l)] = {
                "w": jax.random.normal(sub, (g.size,) + LAYER_SHAPE,
                                       jnp.float32)}
    # 3 separated activation domains + per-client sizes
    rng = np.random.default_rng(seed)
    per = -(-n_clients // 3)
    acts = np.vstack([rng.normal(0, 0.3, (per, EMA_FEATURES)) + off
                      for off in (-6, 0, 6)])[:n_clients]
    acts_dev = jnp.asarray(acts, jnp.float32)
    sizes = rng.integers(50, 700, n_clients)
    return groups, params, acts_dev, sizes


def _run_scale(report, n_clients: int):
    groups, params, acts_dev, sizes = _build_population(n_clients)
    sizes_dev = jnp.asarray(sizes, jnp.float32)
    bound = k_selection_bound(n_clients)
    key = jax.random.PRNGKey(1)
    plans_host, plans_dev, plans_ker = {}, {}, {}

    def host_round():
        # EMA readback + numpy stage 3/4 + host-built weight matrix
        acts = np.asarray(acts_dev)
        cl = cluster_activations(acts, seed=0)
        w, _ = kld_mod.activation_weights(acts, sizes, cl.labels, BETA)
        return federate_client_params(groups, params, w, cl.labels,
                                      n_layers=N_LAYERS,
                                      plan_cache=plans_host)

    @jax.jit
    def _cluster_weight(acts, sizes, key):
        labels, k_sel, sil = cluster_activations_jax(acts, key)
        w, klds = kld_mod.activation_weights_jax(acts, sizes, labels,
                                                 bound, BETA)
        return labels, w

    def device_round(use_kernel=False, plans=None):
        labels, w = _cluster_weight(acts_dev, sizes_dev, key)
        return federate_client_params_device(
            groups, params, w, labels, bound, n_layers=N_LAYERS,
            use_kernel=use_kernel, plan_cache=plans)

    us_host = _bench(host_round, iters=3)
    us_dev = _bench(lambda: device_round(plans=plans_dev), iters=3)
    us_ker = _bench(lambda: device_round(use_kernel=True, plans=plans_ker),
                    iters=3)

    scale = f"{n_clients}c"
    report(f"cluster/host_numpy_{scale}", us_host,
           "EMA readback + numpy kmeans/silhouette/KLD + host W")
    report(f"cluster/fused_jit_{scale}", us_dev, "2 dispatches, in-jit W")
    report(f"cluster/fused_kernel_{scale}", us_ker,
           "pallas kmeans_assign + clustered_agg (interpret)")
    return us_host, min(us_dev, us_ker)


def run(report, tiny: bool = False):
    scales = (32,) if tiny else (32, 128)
    us_host = us_fused = None
    for n in scales:
        us_host, us_fused = _run_scale(report, n)
    report("bench/cluster_round", us_fused,
           f"{scales[-1]}c host={us_host:.0f}us "
           f"speedup={us_host / us_fused:.2f}x")


if __name__ == "__main__":
    run(lambda name, v, d="": print(f"{name},{v:.3f},{d}"))
