"""Paper Tables 15/16 (latency + per-device cut assignments) and the GA
ablations (Tables 24 and 27). Fully analytic -> exactly reproducible.

The base GA solve (paper population, PS=300/GEN=40/seed 0) is computed
once and shared: Table 15 reports its latency, Table 16 reads the
per-profile cut assignment straight out of the same solution (the paper
derives both tables from one optimization), and any ablation setting
that coincides with an already-solved (devices, config) hits the same
cache. ``tiny=True`` shrinks populations/generations for ci_smoke.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.genetic import GAConfig, GAResult, optimize_cuts
from repro.core.latency import (PAPER_DEVICES, PAPER_SERVER, Cut,
                                fedgan_iteration_latency,
                                fedsplitgan_iteration_latency,
                                hflgan_iteration_latency,
                                huscf_iteration_latency,
                                mdgan_iteration_latency,
                                pflgan_iteration_latency)

BATCH = 64

# one GA solve per distinct (devices, config); DeviceProfile is a frozen
# dataclass, so the device tuple hashes by value
_GA_CACHE: Dict[Tuple, Tuple[GAResult, float]] = {}


def shared_ga(devices, config: GAConfig) -> Tuple[GAResult, float]:
    """(result, wall_s) for a GA solve, memoized on (devices, config)."""
    key = (tuple(devices), dataclasses.astuple(config))
    if key not in _GA_CACHE:
        t0 = time.time()
        result = optimize_cuts(list(devices), batch=BATCH, config=config)
        _GA_CACHE[key] = (result, time.time() - t0)
    return _GA_CACHE[key]


def base_config(tiny: bool = False) -> GAConfig:
    return GAConfig(population_size=60 if tiny else 300,
                    generations=10 if tiny else 40, seed=0)


def paper_population(n: int = 100, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [PAPER_DEVICES[i] for i in rng.integers(0, 7, n)]


def table15(n_clients: int = 100, tiny: bool = False) -> List[Dict]:
    """Latency comparison across approaches (paper: 7.8 / 251 / 234 /
    454 / 47.7 / 8.68 s)."""
    devices = paper_population(n_clients)
    ga, ga_wall = shared_ga(devices, base_config(tiny))
    rows = [
        {"approach": "HuSCF-GAN", "latency_s": ga.latency, "paper_s": 7.8},
        {"approach": "PFL-GAN",
         "latency_s": pflgan_iteration_latency(devices, BATCH),
         "paper_s": 251.37},
        {"approach": "FedGAN",
         "latency_s": fedgan_iteration_latency(devices, BATCH),
         "paper_s": 234.6},
        {"approach": "HFL-GAN",
         "latency_s": hflgan_iteration_latency(devices, BATCH),
         "paper_s": 454.22},
        {"approach": "MD-GAN",
         "latency_s": mdgan_iteration_latency(devices, batch=BATCH),
         "paper_s": 47.73},
        {"approach": "Fed-Split-GANs",
         "latency_s": fedsplitgan_iteration_latency(devices, batch=BATCH),
         "paper_s": 8.68},
    ]
    for r in rows:
        r["ratio_vs_huscf"] = r["latency_s"] / rows[0]["latency_s"]
    rows[0]["ga_wall_s"] = ga_wall
    rows[0]["ga_convergence_gen"] = ga.convergence_gen
    return rows


def table16_cuts(n_clients: int = 100, tiny: bool = False) -> List[Dict]:
    """Per-device-profile optimal cut assignment (paper Table 16), read
    off the *shared* Table-15 solve: under profile reduction every
    client of a profile carries the same cut, so the assignment is the
    population solution restricted to one client per profile."""
    devices = paper_population(n_clients)
    ga, _ = shared_ga(devices, base_config(tiny))
    cut_of: Dict[str, Cut] = {}
    for d, c in zip(devices, ga.cuts):
        cut_of.setdefault(d.name, c)
    return [{"device": d.name, "g_head_layers": c.g_h,
             "g_tail_layers": 5 - c.g_t, "d_head_layers": c.d_h,
             "d_tail_layers": 5 - c.d_t}
            for d in PAPER_DEVICES
            for c in (cut_of.get(d.name),) if c is not None]


def table24_ga_hyperparams(tiny: bool = False) -> List[Dict]:
    """GA hyperparameter ablation (paper Table 24)."""
    devices = paper_population(100)
    rows = []
    settings = [
        ("PS=300 CR=0.7 MR=0.01", 300, 0.7, 0.01),
        ("PS=300 CR=0.3 MR=0.01", 300, 0.3, 0.01),
        ("PS=300 CR=0.9 MR=0.01", 300, 0.9, 0.01),
        ("PS=300 CR=0.7 MR=0.1", 300, 0.7, 0.1),
        ("PS=50  CR=0.7 MR=0.01", 50, 0.7, 0.01),
    ]
    if tiny:
        settings = settings[:2]
    gens = 8 if tiny else 25
    for name, ps, cr, mr in settings:
        ga, _ = shared_ga(devices,
                          GAConfig(population_size=20 if tiny else ps,
                                   generations=gens, crossover_rate=cr,
                                   mutation_rate=mr, seed=0))
        rows.append({"setting": name, "latency_s": ga.latency})
    return rows


def table27_profile_vs_client(tiny: bool = False) -> List[Dict]:
    """Profile-based vs client-based GA (paper Table 27: 7.8s/12gen vs
    8.26s/488gen with 100 devices)."""
    devices = paper_population(20 if tiny else 100)
    out = []
    for profile_based in (True, False):
        ga, _ = shared_ga(devices,
                          GAConfig(population_size=40 if tiny else 200,
                                   generations=8 if tiny else 40,
                                   profile_based=profile_based, seed=0))
        out.append({"strategy": "profile" if profile_based else "client",
                    "latency_s": ga.latency,
                    "convergence_gen": ga.convergence_gen})
    return out


def run(report, tiny: bool = False):
    n = 20 if tiny else 100
    for row in table15(n, tiny):
        report(f"table15/{row['approach']}", row["latency_s"],
               f"paper={row['paper_s']} ratio={row['ratio_vs_huscf']:.1f}x")
    for row in table16_cuts(n, tiny):
        report(f"table16/{row['device']}", row["g_head_layers"],
               f"gt={row['g_tail_layers']} dh={row['d_head_layers']} "
               f"dt={row['d_tail_layers']}")
    for row in table24_ga_hyperparams(tiny):
        report(f"table24/{row['setting'].replace(' ', '')}",
               row["latency_s"], "")
    for row in table27_profile_vs_client(tiny):
        report(f"table27/{row['strategy']}", row["latency_s"],
               f"convergence_gen={row['convergence_gen']}")
