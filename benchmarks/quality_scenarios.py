"""Paper Tables 6-13 analogue: classifier metrics + generation scores of
HuSCF-GAN vs baselines per scenario, on the synthetic multi-domain
benchmark (real MNIST-family data is unavailable offline; DESIGN.md §7).

CPU budget: scenario sizes and epochs shrink via `scale`. The paper's
claims validated here are *relative*: HuSCF >= baselines in multi-domain
non-IID settings, and clustering drives the win.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

from repro.baselines import ALL_BASELINES, BaselineConfig
from repro.core import HuSCFConfig, HuSCFTrainer, PAPER_DEVICES
from repro.data import build_scenario, make_class_balanced
from repro.metrics import dataset_score, evaluate, fid
from repro.models.classifier import (features, predict, predict_proba,
                                     train_classifier)


def evaluate_trainer(tr, domains: List[str], n_gen: int = 600,
                     seed: int = 0) -> Dict[str, Dict[str, float]]:
    """Train a CNN on generated data, evaluate on real per-domain test
    sets; also dataset score + FID vs per-domain scoring classifiers."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, n_gen).astype(np.int32)
    gen_imgs, gen_labs = tr.generate(8, labels)
    out = {}
    clf_gen = train_classifier(jax.random.PRNGKey(7), gen_imgs, gen_labs,
                               epochs=4)
    for dom in domains:
        test_i, test_l = make_class_balanced(dom, 30, seed=123)
        rep = evaluate(test_l, predict(clf_gen, test_i))
        # dataset-specific scoring classifier (trained on real data)
        score_i, score_l = make_class_balanced(dom, 60, seed=5)
        clf_real = train_classifier(jax.random.PRNGKey(8), score_i, score_l,
                                    epochs=4)
        gen_score = dataset_score(predict_proba(clf_real, gen_imgs))
        f = fid(features(clf_real, score_i), features(clf_real, gen_imgs))
        out[dom] = {"accuracy": rep.accuracy, "f1": rep.f1,
                    "fpr": rep.fpr, "score": gen_score, "fid": f}
    return out


SCENARIO_DOMAINS = {
    "1dom_iid": ["gratings"], "1dom_noniid": ["gratings"],
    "2dom_iid": ["gratings", "blobs"], "2dom_noniid": ["gratings", "blobs"],
    "2dom_highly_noniid": ["gratings", "blobs"],
    "4dom_iid": ["gratings", "blobs", "checkers", "rings"],
    "2dom_medical": ["rings", "checkers"],
    "2dom_highres": ["checkers", "blobs"],
}


def run_scenario(scenario: str, *, num_clients: int = 6, base_size: int = 96,
                 epochs: int = 4, batch: int = 16,
                 algos=("huscf", "fedgan", "mdgan"), seed: int = 0
                 ) -> Dict[str, Dict]:
    clients = build_scenario(scenario, num_clients=num_clients,
                             base_size=base_size, seed=seed)
    devices = [PAPER_DEVICES[i % 7] for i in range(num_clients)]
    domains = SCENARIO_DOMAINS[scenario]
    results = {}
    for algo in algos:
        t0 = time.time()
        if algo == "huscf":
            tr = HuSCFTrainer(clients, devices,
                              config=HuSCFConfig(batch=batch,
                                                 federate_every=2, seed=seed))
        else:
            tr = ALL_BASELINES[algo](clients, BaselineConfig(
                batch=batch, federate_every=2, seed=seed))
        for _ in range(epochs):
            tr.train_epoch()
        results[algo] = {"metrics": evaluate_trainer(tr, domains),
                         "wall_s": time.time() - t0}
    return results


def run(report, fast: bool = True):
    scenarios = ["2dom_noniid"] if fast else list(SCENARIO_DOMAINS)
    algos = ("huscf", "fedgan", "mdgan") if fast else \
        ("huscf",) + tuple(sorted(ALL_BASELINES))
    for sc in scenarios:
        res = run_scenario(sc, algos=algos)
        for algo, r in res.items():
            for dom, m in r["metrics"].items():
                report(f"quality/{sc}/{algo}/{dom}",
                       r["wall_s"] * 1e6 / max(1, 1),
                       f"acc={m['accuracy']:.3f} f1={m['f1']:.3f} "
                       f"score={m['score']:.2f} fid={m['fid']:.1f}")
