"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus section markers).
``python -m benchmarks.run [--full] [--only SECTION] [--json PATH]``

Sections:
  latency    — paper Tables 15/16/24/27 (analytic, exact reproduction;
               ``--latency-tiny`` shrinks GA populations for CI)
  ga         — GA cut search: host numpy loop vs fused device-resident
               search at population 1000, plus the per-round
               re-optimization microbench (``--ga-tiny`` for CI)
  kernels    — Pallas kernel micro-benches
  federation — fused vs legacy Eq.-16 federation round (32 clients)
               plus the chunk-streamed population-scale round at 1k/8k
               clients (``--fed-tiny`` keeps a 256-client chunked-only
               variant for CI)
  cluster    — stage-3/4 clustered round: host numpy vs device-resident
               jitted/kernel path at 32/128 clients (``--cluster-tiny``
               keeps only the 32-client scale for CI)
  train      — scan-fused device-resident epochs vs per-step loop
               (``--train-tiny`` shrinks to the 2-client CI config)
  serve      — split-serving engine: measured U-shaped cohort
               wall-clock vs the analytic Eq. 7/9 prediction per
               profile mix, plus the Pallas-kernel LM decode tail
               (``--serve-tiny`` for CI)
  quality    — paper Tables 6-13 analogue on synthetic multi-domain data
  kld        — paper Table 17 (activation vs label KLD)
  ablation   — paper Table 23 (component ablation)
  roofline   — derived roofline terms from results/dryrun.jsonl (if present)

``--json PATH`` additionally records the report rows as one snapshot
``{"meta": {...}, "results": {name: {"us_per_call": float, "derived":
str}}}`` *appended* to a ``{"history": [snapshot, ...]}`` trajectory
at PATH — repeat runs accumulate instead of overwriting, so
``results/bench_federation.json`` et al. carry the perf trajectory
across PRs. A pre-trajectory single-snapshot file is absorbed as the
first history entry.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all scenarios/algorithms (slow on CPU)")
    ap.add_argument("--only", default=None, help="run a single section")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a BENCH_*.json dict")
    ap.add_argument("--latency-tiny", action="store_true",
                    help="latency tables with shrunken GA populations "
                         "(CI smoke)")
    ap.add_argument("--ga-tiny", action="store_true",
                    help="ga section at population 64 x 20 clients "
                         "(CI smoke)")
    ap.add_argument("--train-tiny", action="store_true",
                    help="train section at 2 clients x 2 steps (CI smoke)")
    ap.add_argument("--serve-tiny", action="store_true",
                    help="serve section with a small cohort and short "
                         "generation (CI smoke)")
    ap.add_argument("--cluster-tiny", action="store_true",
                    help="cluster section at 32 clients only (CI smoke)")
    ap.add_argument("--fed-tiny", action="store_true",
                    help="federation section: chunk-streamed round only, "
                         "at 256 clients (CI smoke)")
    args = ap.parse_args()

    rows = []

    def _report(name: str, value: float, derived: str = "") -> None:
        rows.append({"name": name, "us_per_call": float(value),
                     "derived": derived})
        print(f"{name},{value:.3f},{derived}", flush=True)

    sections = ["latency", "ga", "kernels", "federation", "cluster",
                "train", "serve", "quality", "kld", "ablation", "roofline"]
    if args.only:
        sections = [args.only]

    t_start = time.time()
    print("name,us_per_call,derived")
    if "latency" in sections:
        from benchmarks import latency_table
        latency_table.run(_report, tiny=args.latency_tiny)
    if "ga" in sections:
        from benchmarks import ga_bench
        ga_bench.run(_report, tiny=args.ga_tiny)
    if "kernels" in sections:
        from benchmarks import kernel_bench
        kernel_bench.run(_report)
    if "federation" in sections:
        from benchmarks import federation_bench
        federation_bench.run(_report, tiny=args.fed_tiny)
    if "cluster" in sections:
        from benchmarks import cluster_bench
        cluster_bench.run(_report, tiny=args.cluster_tiny)
    if "train" in sections:
        from benchmarks import train_bench
        train_bench.run(_report, tiny=args.train_tiny)
    if "serve" in sections:
        from benchmarks import serve_bench
        serve_bench.run(_report, tiny=args.serve_tiny)
    if "quality" in sections:
        from benchmarks import quality_scenarios
        quality_scenarios.run(_report, fast=not args.full)
    if "kld" in sections:
        from benchmarks import kld_comparison
        kld_comparison.run(_report)
    if "ablation" in sections:
        from benchmarks import ablation_components
        ablation_components.run(_report)
    if "roofline" in sections:
        path = os.path.join(os.path.dirname(__file__), "..", "results",
                            "dryrun.jsonl")
        if os.path.exists(path):
            from repro.launch.roofline import analyze_record, load
            for rec in sorted(load(path),
                              key=lambda r: (r["arch"], r["shape"])):
                a = analyze_record(rec)
                if a is None:
                    continue
                mesh = "2pod" if rec["multi_pod"] else "1pod"
                _report(f"roofline/{a['arch']}/{a['shape']}/{mesh}",
                        a["bound_s"] * 1e6,
                        f"dom={a['dominant']} useful={a['useful_ratio']:.2f}")
        else:
            print("# roofline: results/dryrun.jsonl missing — run "
                  "python -m repro.launch.dryrun --all first",
                  file=sys.stderr)
    wall = time.time() - t_start
    print(f"# total wall: {wall:.1f}s", file=sys.stderr)

    if args.json:
        snapshot = {
            "meta": {
                "argv": sys.argv[1:],
                "sections": sections,
                "unix_time": int(t_start),
                "total_wall_s": round(wall, 3),
            },
            "results": {r["name"]: {"us_per_call": r["us_per_call"],
                                    "derived": r["derived"]}
                        for r in rows},
        }
        history = []
        if os.path.exists(args.json):
            try:
                with open(args.json) as f:
                    prev = json.load(f)
                if isinstance(prev, dict) and \
                        isinstance(prev.get("history"), list):
                    history = prev["history"]
                elif isinstance(prev, dict) and "results" in prev:
                    # pre-trajectory files were a bare snapshot dict
                    history = [prev]
                else:
                    raise TypeError("not a snapshot/trajectory")
            except (OSError, json.JSONDecodeError, TypeError):
                print(f"# {args.json} unreadable, starting a fresh "
                      "trajectory", file=sys.stderr)
        history.append(snapshot)
        out = {"history": history}
        d = os.path.dirname(args.json)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# json report: {args.json} ({len(history)} snapshots)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
