"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus section markers).
``python -m benchmarks.run [--full] [--only SECTION]``

Sections:
  latency   — paper Tables 15/16/24/27 (analytic, exact reproduction)
  kernels   — Pallas kernel micro-benches
  quality   — paper Tables 6-13 analogue on synthetic multi-domain data
  kld       — paper Table 17 (activation vs label KLD)
  ablation  — paper Table 23 (component ablation)
  roofline  — derived roofline terms from results/dryrun.jsonl (if present)
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _report(name: str, value: float, derived: str = "") -> None:
    print(f"{name},{value:.3f},{derived}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all scenarios/algorithms (slow on CPU)")
    ap.add_argument("--only", default=None, help="run a single section")
    args = ap.parse_args()

    sections = ["latency", "kernels", "quality", "kld", "ablation",
                "roofline"]
    if args.only:
        sections = [args.only]

    t_start = time.time()
    print("name,us_per_call,derived")
    if "latency" in sections:
        from benchmarks import latency_table
        latency_table.run(_report)
    if "kernels" in sections:
        from benchmarks import kernel_bench
        kernel_bench.run(_report)
    if "quality" in sections:
        from benchmarks import quality_scenarios
        quality_scenarios.run(_report, fast=not args.full)
    if "kld" in sections:
        from benchmarks import kld_comparison
        kld_comparison.run(_report)
    if "ablation" in sections:
        from benchmarks import ablation_components
        ablation_components.run(_report)
    if "roofline" in sections:
        path = os.path.join(os.path.dirname(__file__), "..", "results",
                            "dryrun.jsonl")
        if os.path.exists(path):
            from repro.launch.roofline import analyze_record, load
            for rec in sorted(load(path),
                              key=lambda r: (r["arch"], r["shape"])):
                a = analyze_record(rec)
                if a is None:
                    continue
                mesh = "2pod" if rec["multi_pod"] else "1pod"
                _report(f"roofline/{a['arch']}/{a['shape']}/{mesh}",
                        a["bound_s"] * 1e6,
                        f"dom={a['dominant']} useful={a['useful_ratio']:.2f}")
        else:
            print("# roofline: results/dryrun.jsonl missing — run "
                  "python -m repro.launch.dryrun --all first",
                  file=sys.stderr)
    print(f"# total wall: {time.time() - t_start:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
