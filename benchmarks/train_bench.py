"""Training-epoch macro-benchmark: fused device-resident epochs vs the
per-step oracle loop (one dispatch + blocking mid-activation readback
+ Python EMA per step), at 8/16/32 clients.

Batch 1 on purpose: the paper cGAN's conv FLOPs scale with
clients x batch, and on a small CPU container the conv compute buries
everything else within a few samples — batch 1 is the regime where the
per-step host overheads the fused path eliminates (per-step dispatch
of a ~300-leaf state pytree, device->host mid sync, per-client Python
EMA) are visible at all. Per-step wall-clock is still conv-dominated
here, so CPU speedups understate the accelerator win the same way the
PR 2 sharded-round numbers only measure collective overhead; the
headline ``bench/train_epoch`` row records the honest ratio plus the
absolute per-step host overhead eliminated.

The fused rows use the backend-auto unroll (full unroll on CPU):
XLA:CPU only multithreads the entry computation, so a true while-loop
scan body runs single-threaded — the ``fused_scan_loop`` row keeps
that penalty on the record (EXPERIMENTS.md §Device-resident epochs).

``tiny=True`` (scripts/ci_smoke.sh) runs 2 clients x 2 steps so the
bench path cannot rot without tripping CI — a rot canary, not a perf
signal: at 2 clients the per-op overheads dominate and the large
fused module schedules worse than the small per-step one (measured
0.36x), while the 8/16/32-client rows show the real ordering.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import HuSCFConfig, HuSCFTrainer, PAPER_DEVICES
from repro.core.latency import Cut

CLIENT_COUNTS = (8, 16, 32)
N_STEPS = 2
BATCH = 1
_CUTS = (Cut(1, 3, 1, 3), Cut(2, 4, 2, 4), Cut(1, 4, 2, 3), Cut(2, 3, 1, 4))


def _make_trainer(n_clients: int, fused: bool, n_steps: int,
                  epoch_unroll=None):
    from repro.data import build_scenario
    clients = build_scenario("2dom_iid", num_clients=n_clients,
                             base_size=16, seed=0)
    devices = [PAPER_DEVICES[i % len(_CUTS)] for i in range(n_clients)]
    cuts = [_CUTS[i % len(_CUTS)] for i in range(n_clients)]
    cfg = HuSCFConfig(batch=BATCH, steps_per_epoch=n_steps,
                      federate_every=10 ** 6, seed=0, fused_epoch=fused,
                      epoch_unroll=epoch_unroll)
    return HuSCFTrainer(clients, devices, cuts=cuts, config=cfg)


def _time_epoch(tr, n_steps: int, reps: int = 2) -> float:
    """Warm (compile + first run discarded) us per step, averaged over
    ``reps`` epochs — single-epoch samples swing +-35% on a shared
    container."""
    tr.train_steps(n_steps)
    t0 = time.perf_counter()
    for _ in range(reps):
        tr.train_steps(n_steps)
    return (time.perf_counter() - t0) / (reps * n_steps) * 1e6


def run(report, tiny: bool = False):
    counts = (2,) if tiny else CLIENT_COUNTS
    n_steps = 2 if tiny else N_STEPS
    results = {}
    for n in counts:
        us_fused = _time_epoch(_make_trainer(n, True, n_steps), n_steps)
        us_step = _time_epoch(_make_trainer(n, False, n_steps), n_steps)
        results[n] = (us_fused, us_step)
        report(f"train/fused_epoch_{n}c_b{BATCH}", us_fused,
               f"{1e6 / us_fused:.3f} steps/s, {n_steps} steps/dispatch")
        report(f"train/per_step_{n}c_b{BATCH}", us_step,
               f"{1e6 / us_step:.3f} steps/s, 1 dispatch+sync/step")
    n = max(counts)
    # the true while-loop scan at the largest count, to keep the
    # XLA:CPU single-threaded-loop-body penalty on the record
    us_loop = _time_epoch(_make_trainer(n, True, n_steps, epoch_unroll=1),
                          n_steps)
    report(f"train/fused_scan_loop_{n}c_b{BATCH}", us_loop,
           f"{1e6 / us_loop:.3f} steps/s, unroll=1 while-loop body")
    us_fused, us_step = results[n]
    # distinct headline key for the CI smoke config: its 2-client
    # numbers would otherwise interleave with the real 32-client
    # trajectory under one name and read as a perf flip
    headline = "bench/train_epoch_tiny" if tiny else "bench/train_epoch"
    report(headline, us_fused,
           f"per_step={us_step:.0f}us speedup={us_step / us_fused:.2f}x "
           f"host_overhead_cut={us_step - us_fused:.0f}us/step at {n}c")


if __name__ == "__main__":
    run(lambda name, v, d="": print(f"{name},{v:.3f},{d}"))
