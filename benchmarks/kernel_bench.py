"""Kernel micro-benchmarks: interpret-mode Pallas vs pure-jnp oracle.

On CPU the numbers characterize the *oracle* path (the Pallas bodies run
interpreted); on TPU re-run with REPRO_PALLAS_COMPILE=1 for real kernel
timings. Reported as name,us_per_call,derived-GB/s.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _bench(fn, *args, iters: int = 5) -> float:
    jax.block_until_ready(fn(*args))   # warmup/compile, one call
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(report):
    key = jax.random.PRNGKey(0)
    # weighted_agg: 16 clients x 3M params (the GAN federation round)
    K, D = 16, 3_000_000
    x = jax.random.normal(key, (K, D), jnp.float32)
    w = jax.nn.softmax(jax.random.normal(key, (K,)))
    us = _bench(ops.weighted_agg, x, w)
    gbps = K * D * 4 / (us / 1e6) / 1e9
    report("kernel/weighted_agg_16x3M", us, f"{gbps:.1f}GB/s")
    us = _bench(jax.jit(ref.weighted_agg_ref), x, w)
    report("kernel/weighted_agg_ref", us, "oracle")

    # clustered multi-output aggregation: 16 (layer, cluster) segments
    # over the same 16 x 3M stacked buffer (the fused federation round)
    S = 16
    seg_w = jax.nn.softmax(jax.random.normal(key, (S, K)), axis=1)
    us = _bench(ops.clustered_agg, seg_w, x)
    gbps = (K + S) * D * 4 / (us / 1e6) / 1e9
    report("kernel/clustered_agg_16seg_16x3M", us, f"{gbps:.1f}GB/s")
    us = _bench(jax.jit(ref.clustered_agg_ref), seg_w, x)
    report("kernel/clustered_agg_ref", us, "oracle")

    # kmeans assign: 256 clients x 6272-dim activations, 4 centers
    x = jax.random.normal(key, (256, 6272))
    c = jax.random.normal(key, (4, 6272))
    report("kernel/kmeans_assign_256x6272", _bench(ops.kmeans_assign, x, c),
           "")
    report("kernel/kmeans_assign_ref",
           _bench(jax.jit(ref.kmeans_assign_ref), x, c), "oracle")

    # flash decode: B=4, H=32 (kv 8), 4k cache (interpret mode on CPU
    # is the oracle-path timing; use 32k+ on real TPU)
    B, H, KV, hd, S = 4, 32, 8, 128, 4096
    q = jax.random.normal(key, (B, H, hd), jnp.bfloat16)
    k = jax.random.normal(key, (B, S, KV, hd), jnp.bfloat16)
    v = jax.random.normal(key, (B, S, KV, hd), jnp.bfloat16)
    clen = jnp.asarray(S, jnp.int32)
    us = _bench(ops.flash_decode, q, k, v, clen, iters=2)
    stream_gb = 2 * B * S * KV * hd * 2 / 1e9
    report("kernel/flash_decode_4k", us,
           f"streams {stream_gb:.2f}GB/call")
    report("kernel/flash_decode_ref",
           _bench(jax.jit(ref.flash_decode_ref), q, k, v, clen, iters=2),
           "oracle")
