"""GA cut-search bench: host numpy loop vs fused device-resident search.

Two questions, matching the acceptance bar for the on-device GA:

* full-search throughput — ``optimize_cuts`` at population 1000 on the
  paper's 100-client population, host oracle vs fused (same seed
  protocol; solution quality must not regress, wall must drop >= 20x
  on CPU);
* per-round re-optimization — the trainer's steady-state cost of
  ``CutSearcher.run`` on a *staged* searcher (what ``reoptimize_every``
  pays each federation round), with the one-time build/compile cost
  reported separately.

``tiny=True`` shrinks population/generations for ci_smoke.
"""
from __future__ import annotations

import time
from typing import List

import jax

from benchmarks.latency_table import BATCH, paper_population
from repro.core.genetic import CutSearcher, GAConfig, optimize_cuts


def _wall(fn, repeats: int = 1) -> float:
    """Median wall seconds over ``repeats`` calls."""
    times: List[float] = []
    for _ in range(repeats):
        t0 = time.time()
        fn()
        times.append(time.time() - t0)
    times.sort()
    return times[len(times) // 2]


def run(report, tiny: bool = False):
    n_clients = 20 if tiny else 100
    devices = paper_population(n_clients)
    pop = 64 if tiny else 1000
    gens = 10 if tiny else 60
    cfg = GAConfig(population_size=pop, generations=gens, seed=0)

    # --- full search: host oracle ------------------------------------
    t0 = time.time()
    host = optimize_cuts(devices, batch=BATCH, config=cfg, fused=False)
    host_wall = time.time() - t0
    report(f"ga/host_pop{pop}", host_wall * 1e6,
           f"latency={host.latency:.4f}s gens={host.generations_run}")

    # --- full search: fused (compile separated from steady state) ----
    searcher = CutSearcher(devices, batch=BATCH, config=cfg)
    key = jax.random.PRNGKey(cfg.seed)
    t0 = time.time()
    jax.block_until_ready(searcher.run(key))          # trace + compile
    compile_wall = time.time() - t0
    report(f"ga/fused_compile_pop{pop}", compile_wall * 1e6,
           "one-time trace+compile (shared across same-shape populations)")

    fused_wall = _wall(lambda: jax.block_until_ready(searcher.run(key)),
                       repeats=3 if tiny else 5)
    fused = searcher.to_result(searcher.run(key))
    speedup = host_wall / fused_wall
    report(f"ga/fused_pop{pop}", fused_wall * 1e6,
           f"latency={fused.latency:.4f}s gens={fused.generations_run} "
           f"speedup={speedup:.1f}x "
           f"quality_ok={fused.latency <= host.latency + 1e-9}")

    # --- per-round re-optimization (trainer steady state) ------------
    # fresh keys per round, like the trainer's _ga_key chain; run() is
    # the transfer-free dispatch, to_result() adds the readback +
    # host-f64 re-evaluation the trainer does only on adoption
    keys = jax.random.split(key, 8)
    reopt_wall = _wall(
        lambda: jax.block_until_ready(searcher.run(keys[0])),
        repeats=3 if tiny else 5)
    report("ga/reopt_dispatch", reopt_wall * 1e6,
           "per-round search dispatch (device arrays only)")
    full_wall = _wall(lambda: searcher.to_result(searcher.run(keys[1])),
                      repeats=3 if tiny else 5)
    report("ga/reopt_round", full_wall * 1e6,
           "dispatch + readback + host-f64 re-eval (cut adoption)")
