"""Regenerate the data-driven sections of EXPERIMENTS.md from
results/*.jsonl artifacts. Idempotent: replaces the <!-- MARK --> spans.

    PYTHONPATH=src python scripts/fill_experiments.py
"""
import json
import os
import re
import sys

sys.path.insert(0, "src")

from repro.launch.roofline import analyze_record, load, markdown_table

ROOT = os.path.join(os.path.dirname(__file__), "..")


def dryrun_summary(rows):
    ok = sum(1 for r in rows if "cost" in r)
    sk = sum(1 for r in rows if "skipped" in r)
    er = sum(1 for r in rows if "error" in r)
    over = [r for r in rows if r.get("memory", {}).get("peak_bytes", 0)
            > 16 * 2 ** 30]
    lines = [f"Latest matrix: **{ok} compiled OK, {sk} skipped by design, "
             f"{er} errors** (out of {len(rows)} records)."]
    if over:
        lines.append("Over-HBM pairs: " + ", ".join(
            f"{r['arch']}x{r['shape']}" for r in over))
    else:
        lines.append("Every compiled pair fits within 16 GiB/chip HBM "
                     "(`memory_analysis` peak).")
    # compile time stats
    cs = [r.get("compile_s", 0) for r in rows if "cost" in r]
    if cs:
        lines.append(f"Compile times: median {sorted(cs)[len(cs)//2]:.0f}s, "
                     f"max {max(cs):.0f}s (single-core CPU lowering of the "
                     f"256/512-chip SPMD programs).")
    return "\n".join(lines)


def paper_mode_table(path):
    if not os.path.exists(path):
        return "(paper-mode dry-run not yet recorded)"
    rows = [json.loads(l) for l in open(path)]
    out = ["| subject | mesh | variant | HLO flops | collective B "
           "(by type) | peak HBM |", "|---|---|---|---|---|---|"]
    seen = {}
    for r in rows:
        key = (r["arch"], r["multi_pod"], r.get("variant", ""))
        seen[key] = r
    for (_, mp, var), r in sorted(seen.items(), key=str):
        coll = r.get("collectives", {})
        by_type = " ".join(f"{k}={v:.1e}" for k, v in sorted(coll.items())
                           if k != "total")
        out.append(
            f"| {r['arch']} | {'2pod' if mp else '1pod'} | {var or '—'} | "
            f"{r['cost'].get('flops', 0):.2e} | total={coll.get('total', 0):.2e} "
            f"({by_type}) | {r['memory'].get('peak_bytes', 0)/2**30:.2f} GiB |")
    return "\n".join(out)


def splice(text, mark, payload):
    return re.sub(f"<!-- {mark} -->.*?(?=\n## |\n### |\\Z)",
                  f"<!-- {mark} -->\n\n{payload}\n", text, flags=re.S)


def main():
    exp_path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(exp_path).read()
    dr_path = os.path.join(ROOT, "results", "dryrun.jsonl")
    if os.path.exists(dr_path):
        rows = load(dr_path)
        text = splice(text, "DRYRUN_SUMMARY", dryrun_summary(rows))
        text = splice(text, "ROOFLINE_1POD", markdown_table(rows, False))
        text = splice(text, "ROOFLINE_2POD", markdown_table(rows, True))
    text = splice(text, "PAPER_MODE", paper_mode_table(
        os.path.join(ROOT, "results", "dryrun_paper.jsonl")))
    open(exp_path, "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
