#!/usr/bin/env bash
# Tier-1 smoke gate: the full pytest suite plus the kernel
# micro-benches with a JSON perf report. Fails on any nonzero exit.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

mkdir -p results
python -m benchmarks.run --only kernels --json results/bench_kernels.json

echo "ci_smoke: OK"
