#!/usr/bin/env bash
# Tier-1 smoke gate: the full pytest suite plus the kernel
# micro-benches with a JSON perf report. Fails on any nonzero exit.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Sharded suite excluded here: it reruns inline below under the forced
# device count (running it in this invocation too would pay each check
# twice, once per-test via 8-device subprocesses).
python -m pytest -x -q --ignore=tests/test_federation_sharded.py

# Multi-device suite, second invocation: the forced host-device count
# binds at backend init, so the sharded federation tests get their own
# pytest process with 8 CPU devices (the multihost fixture then runs
# its checks inline instead of via per-test subprocesses). Any
# caller-supplied device-count flag is stripped first (the last
# duplicate wins in XLA's flag parsing; `|| true` because grep -v
# "selected nothing" exits 1 under pipefail), and the platform is
# pinned to cpu so accelerator hosts still get the forced CPU pool —
# the sh twin of repro.launch.mesh.forced_device_env.
CI_XLA_FLAGS=$(echo "${XLA_FLAGS:-}" | tr ' ' '\n' \
    | { grep -v -- --xla_force_host_platform_device_count || true; } \
    | tr '\n' ' ')
XLA_FLAGS="--xla_force_host_platform_device_count=8 ${CI_XLA_FLAGS}" \
    JAX_PLATFORMS=cpu python -m pytest -x -q tests/test_federation_sharded.py

mkdir -p results
python -m benchmarks.run --only kernels --json results/bench_kernels.json

# Scan-fused training-epoch bench, tiny config (2 clients x 2 steps):
# keeps the train_bench path compiling/running and appends the result
# to the results/ perf trajectory.
python -m benchmarks.run --only train --train-tiny \
    --json results/bench_train.json

# Stage-3/4 clustered-round bench, tiny config (32 clients): exercises
# fused_cluster ON (jitted cluster+weight + in-jit weight matrix, with
# and without the Pallas kmeans_assign kernel) and OFF (the host-numpy
# oracle round) in one invocation, appending to the federation perf
# trajectory. The pytest suite above additionally pins the two paths
# to each other (tests/test_cluster_fused.py).
python -m benchmarks.run --only cluster --cluster-tiny \
    --json results/bench_federation.json

# Chunk-streamed population round, tiny config (256 clients, chunk 64):
# keeps the O(chunk + clusters) streaming path compiling/running and
# its workset-vs-dense memory ratio on the same trajectory.
python -m benchmarks.run --only federation --fed-tiny \
    --json results/bench_federation.json

# Split-serving engine, tiny config (8-request cohorts, short LM
# generation): keeps the SplitProgram executor + analytic-prediction
# comparison and the Pallas decode tail compiling/running; the
# measured-vs-analytic ratios land on their own perf trajectory.
python -m benchmarks.run --only serve --serve-tiny \
    --json results/bench_serve.json

# On-device GA cut search, tiny config (population 64 x 20 clients):
# host oracle vs fused search plus the per-round re-optimization
# microbench, appended to its own perf trajectory.
python -m benchmarks.run --only ga --ga-tiny \
    --json results/bench_ga.json

# Analytic latency tables with shrunken GA populations: keeps the
# shared-solve (Tables 15/16 from one optimization) path exercised.
python -m benchmarks.run --only latency --latency-tiny \
    --json results/bench_latency.json

echo "ci_smoke: OK"
