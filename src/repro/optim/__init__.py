from repro.optim.optimizers import adam, sgd, AdamState, SGDState
from repro.optim.schedules import constant, warmup_cosine, linear_decay

__all__ = ["adam", "sgd", "AdamState", "SGDState", "constant",
           "warmup_cosine", "linear_decay"]
