"""Minimal optimizer library (optax is not available offline).

Optimizers are (init, update) pairs operating on parameter pytrees.
`update(state, grads, params) -> (new_state, new_params)`.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import nn


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, grad_clip: Optional[float] = None):
    """lr is a float or a callable step -> lr."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamState(jnp.zeros((), jnp.int32), zeros,
                         jax.tree_util.tree_map(jnp.copy, zeros))

    def update(state: AdamState, grads, params):
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        if grad_clip is not None:
            gn = nn.global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip / (gn + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        step = state.step + 1
        lr_t = lr_fn(step)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, mu, nu)
        return AdamState(step, mu, nu), new_params

    return init, update


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: Any


def sgd(lr, momentum: float = 0.0):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        mom = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return SGDState(jnp.zeros((), jnp.int32), mom)

    def update(state: SGDState, grads, params):
        step = state.step + 1
        lr_t = lr_fn(step)
        if momentum:
            mom = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state.momentum, grads)
            eff = mom
        else:
            mom = state.momentum
            eff = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        new_params = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32) - lr_t * g).astype(p.dtype),
            params, eff)
        return SGDState(step, mom), new_params

    return init, update
