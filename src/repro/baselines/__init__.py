from repro.baselines.common import BaselineConfig, PopulationTrainer
from repro.baselines.fedgan import FedGANTrainer
from repro.baselines.mdgan import MDGANTrainer
from repro.baselines.fed_split_gan import FedSplitGANTrainer
from repro.baselines.pfl_gan import PFLGANTrainer
from repro.baselines.hfl_gan import HFLGANTrainer

ALL_BASELINES = {
    "fedgan": FedGANTrainer,
    "mdgan": MDGANTrainer,
    "fed_split_gan": FedSplitGANTrainer,
    "pfl_gan": PFLGANTrainer,
    "hfl_gan": HFLGANTrainer,
}
