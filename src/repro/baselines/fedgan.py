"""FedGAN (Rasouli et al., 2020): vanilla FedAvg over full local cGANs,
weighted by local dataset size."""
from __future__ import annotations

import numpy as np

from repro.baselines.common import PopulationTrainer, fedavg_population


class FedGANTrainer(PopulationTrainer):
    name = "fedgan"

    def federate(self) -> None:
        w = self.sizes.astype(np.float64)
        self.g_params = fedavg_population(self.g_params, w)
        self.d_params = fedavg_population(self.d_params, w)
