"""HFL-GAN (Petch et al., 2025) — hierarchical federated GAN.

Clients are grouped by cosine similarity of their (flattened) generator
updates; FedAvg runs *locally* within groups every round and *globally*
(across group aggregates) every `global_every` rounds. The scheme trains
two generators per client (hence its 2x latency, paper §6.2); we model
the quality-relevant hierarchy with the primary generator and account
for the dual-generator cost in the latency model only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.common import (BaselineConfig, PopulationTrainer,
                                    fedavg_population)
from repro.core.clustering import kmeans
from repro.models.nn import tree_weighted_sum


class HFLGANTrainer(PopulationTrainer):
    name = "hfl_gan"

    def __init__(self, clients, config: BaselineConfig = BaselineConfig(),
                 n_groups: int = 2, global_every: int = 3):
        super().__init__(clients, config)
        self.n_groups = min(n_groups, self.K)
        self.global_every = global_every
        self._fed_rounds = 0

    def _flat_g(self) -> np.ndarray:
        leaves = [np.asarray(x).reshape(self.K, -1)
                  for x in jax.tree_util.tree_leaves(self.g_params)]
        flat = np.concatenate(leaves, axis=1)
        # project for tractable cosine clustering
        rng = np.random.default_rng(0)
        proj = rng.normal(0, 1, (flat.shape[1], 64)).astype(np.float32)
        emb = flat @ proj
        return emb / (np.linalg.norm(emb, axis=1, keepdims=True) + 1e-8)

    def federate(self) -> None:
        self._fed_rounds += 1
        emb = self._flat_g()
        labels, _, _ = kmeans(emb, self.n_groups, seed=0)
        # intra-group FedAvg
        for net in ("g_params", "d_params"):
            params = getattr(self, net)
            for c in np.unique(labels):
                idx = np.flatnonzero(labels == c)
                w = self.sizes[idx].astype(np.float64)
                w = w / w.sum()
                sub = jax.tree_util.tree_map(lambda x: x[idx], params)
                avg = tree_weighted_sum(sub, jnp.asarray(w))
                params = jax.tree_util.tree_map(
                    lambda full, a: full.at[idx].set(
                        jnp.broadcast_to(a, (idx.size,) + a.shape
                                         ).astype(full.dtype)), params, avg)
            setattr(self, net, params)
        # periodic global round
        if self._fed_rounds % self.global_every == 0:
            self.g_params = fedavg_population(
                self.g_params, self.sizes.astype(np.float64))
            self.d_params = fedavg_population(
                self.d_params, self.sizes.astype(np.float64))
