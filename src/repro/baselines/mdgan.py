"""MD-GAN (Hardy et al., 2019).

Single generator on the server; one discriminator per client. Each
iteration the server generates two synthetic batches per client (X_d to
train D, X_g to compute G feedback); each client updates its local D and
returns the generator-loss gradients; the server averages them.
Discriminators are periodically swapped between clients.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.common import (BaselineConfig, PopulationTrainer,
                                    disc_forward_dict, gen_forward_dict,
                                    merge_bn, _as_dict)
from repro.data.partition import ClientSpec
from repro.models import gan
from repro.models.gan import Z_DIM
from repro.optim import adam


class MDGANTrainer(PopulationTrainer):
    name = "mdgan"

    def __init__(self, clients, config: BaselineConfig = BaselineConfig()):
        super().__init__(clients, config)
        # single server generator replaces the population of generators
        key = jax.random.PRNGKey(config.seed + 17)
        self.g_server = _as_dict(gan.init_generator(key))
        opt_init_g, self._upd_g2 = adam(config.lr, b1=config.adam_b1)
        self.opt_gs = opt_init_g(self.g_server)
        self._step2 = jax.jit(self._build_mdgan_step())

    def _build_mdgan_step(self):
        upd_d, upd_g = self._upd_d, self._upd_g2

        def step(g_server, d_params, opt_gs, opt_d, batch):
            real_img, real_y, z_d, z_g, fake_y = batch

            # server generates (no grad into G for the D update)
            fake_d, _ = gen_forward_dict(g_server, z_d.reshape(-1, Z_DIM),
                                         fake_y.reshape(-1), True)
            fake_d = jax.lax.stop_gradient(
                fake_d.reshape(real_img.shape[0], -1, 28, 28, 1))

            def d_loss_k(dp, rimg, ry, fimg, fy):
                lr_, nd = disc_forward_dict(dp, rimg, ry, True)
                lf_, _ = disc_forward_dict(dp, fimg, fy, True)
                return gan.d_loss_fn(lr_, lf_), nd

            def d_update(dp, od, rimg, ry, fimg, fy):
                (ld, nd_bn), gd = jax.value_and_grad(
                    d_loss_k, has_aux=True)(dp, rimg, ry, fimg, fy)
                od, dn = upd_d(od, gd, dp)
                return merge_bn(dn, nd_bn), od, ld

            d_new, opt_d, loss_d = jax.vmap(d_update)(
                d_params, opt_d, real_img, real_y, fake_d, fake_y)

            # generator feedback: mean G loss across client discriminators
            def g_loss(gs):
                fake_g, ng = gen_forward_dict(gs, z_g.reshape(-1, Z_DIM),
                                              fake_y.reshape(-1), True)
                fake_g = fake_g.reshape(real_img.shape[0], -1, 28, 28, 1)
                logits = jax.vmap(
                    lambda dp, fi, fy: disc_forward_dict(dp, fi, fy, True)[0]
                )(d_new, fake_g, fake_y)
                return gan.g_loss_fn(logits.reshape(-1)), ng

            (loss_g, g_bn), grads_g = jax.value_and_grad(
                g_loss, has_aux=True)(g_server)
            opt_gs, g_new = upd_g(opt_gs, grads_g, g_server)
            g_new = merge_bn(g_new, g_bn)
            return g_new, d_new, opt_gs, opt_d, loss_d.mean(), loss_g

        return step

    def train_steps(self, n: int) -> Dict[str, float]:
        loss_d = loss_g = 0.0
        for _ in range(n):
            b = self.cfg.batch
            imgs, ys = [], []
            for c in self.clients:
                idx = self._rng.integers(0, c.n, b)
                imgs.append(c.images[idx]); ys.append(c.labels[idx])
            z_d = self._rng.normal(0, 1, (self.K, b, Z_DIM)).astype(np.float32)
            z_g = self._rng.normal(0, 1, (self.K, b, Z_DIM)).astype(np.float32)
            fy = self._rng.integers(0, gan.NUM_CLASSES, (self.K, b)).astype(np.int32)
            batch = (np.stack(imgs), np.stack(ys), z_d, z_g, fy)
            (self.g_server, self.d_params, self.opt_gs, self.opt_d,
             ld, lg) = self._step2(self.g_server, self.d_params,
                                   self.opt_gs, self.opt_d, batch)
            loss_d, loss_g = float(ld), float(lg)
        return {"loss_d": loss_d, "loss_g": loss_g}

    def federate(self) -> None:
        # MD-GAN swaps discriminators between clients (anti-overfitting)
        perm = self._rng.permutation(self.K)
        self.d_params = jax.tree_util.tree_map(lambda x: x[perm], self.d_params)
        self.opt_d = jax.tree_util.tree_map(
            lambda x: x[perm] if hasattr(x, "ndim") and x.ndim > 0
            and x.shape[0] == self.K else x, self.opt_d)

    def generate(self, n_per_client_batch: int, labels: np.ndarray):
        gen = jax.jit(lambda gp, z, y: gen_forward_dict(gp, z, y, False)[0])
        out_imgs, out_labs, i = [], [], 0
        while i < len(labels):
            take = min(256, len(labels) - i)
            lab = labels[i: i + take].astype(np.int32)
            z = self._rng.normal(0, 1, (take, Z_DIM)).astype(np.float32)
            out_imgs.append(np.asarray(gen(self.g_server, z, lab)))
            out_labs.append(lab)
            i += take
        return np.concatenate(out_imgs), np.concatenate(out_labs)
