"""Federated Split GANs (Kortoçi et al., 2022).

Generator on the server. Each client's discriminator is *split* at a
capability-dependent cut: D-head on the client, D-tail shared on the
server. Client D-heads are FedAvg'd every few epochs. Synthetic images
travel server -> client (the privacy weakness the paper calls out).

Simulation: one shared cut (the scheme's median device) so heads stack;
heterogeneous cuts are the HuSCF contribution, not this baseline's.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.common import (BaselineConfig, PopulationTrainer,
                                    fedavg_population, gen_forward_dict,
                                    merge_bn, _as_dict)
from repro.models import gan
from repro.models.gan import DISC_LAYER_DEFS, Z_DIM
from repro.optim import adam

D_CUT = 2  # client holds D layers [0, D_CUT); server the rest


class FedSplitGANTrainer(PopulationTrainer):
    name = "fed_split_gan"

    def __init__(self, clients, config: BaselineConfig = BaselineConfig()):
        super().__init__(clients, config)
        key = jax.random.PRNGKey(config.seed + 31)
        kg, kd = jax.random.split(key)
        self.g_server = _as_dict(gan.init_generator(kg))
        # d_params population: keep only head layers stacked
        self.d_heads = {str(l): self.d_params[str(l)] for l in range(D_CUT)}
        keys = jax.random.split(kd, len(DISC_LAYER_DEFS) - D_CUT)
        self.d_tail = {str(l): DISC_LAYER_DEFS[l][0](keys[l - D_CUT], jnp.float32)
                       for l in range(D_CUT, len(DISC_LAYER_DEFS))}
        og, self._upd_gs = adam(config.lr, b1=config.adam_b1)
        od, self._upd_dh = adam(config.lr, b1=config.adam_b1)
        ot, self._upd_dt = adam(config.lr, b1=config.adam_b1)
        self.opt_gs = og(self.g_server)
        self.opt_dh = od(self.d_heads)
        self.opt_dt = ot(self.d_tail)
        self._step3 = jax.jit(self._build_split_step())

    def _build_split_step(self):
        n_d = len(DISC_LAYER_DEFS)

        def disc_split(heads, tail, img, y, train):
            """heads: stacked [K,...]; img [K,b,...]. Returns logits [K,b]."""
            def head_fn(hp, im, yy):
                x = (im, yy)
                new = {}
                for l in range(D_CUT):
                    x, new[str(l)] = DISC_LAYER_DEFS[l][1](hp[str(l)], x, train)
                return x, new
            acts, new_heads = jax.vmap(head_fn)(heads, img, y)
            k, b = acts.shape[0], acts.shape[1]
            x = acts.reshape((k * b,) + acts.shape[2:])
            new_tail = {}
            for l in range(D_CUT, n_d):
                x, new_tail[str(l)] = DISC_LAYER_DEFS[l][1](tail[str(l)], x, train)
            return x.reshape(k, b), new_heads, new_tail

        def step(g_server, d_heads, d_tail, opts, batch):
            opt_gs, opt_dh, opt_dt = opts
            real_img, real_y, z, fake_y = batch
            k, b = real_img.shape[0], real_img.shape[1]

            def d_loss(dp):
                heads, tail = dp
                fake, _ = gen_forward_dict(g_server, z.reshape(-1, Z_DIM),
                                           fake_y.reshape(-1), True)
                fake = jax.lax.stop_gradient(fake.reshape(k, b, 28, 28, 1))
                lr_, nh, nt = disc_split(heads, tail, real_img, real_y, True)
                lf_, _, _ = disc_split(heads, tail, fake, fake_y, True)
                return (gan.d_loss_fn(lr_.reshape(-1), lf_.reshape(-1)),
                        (nh, nt))

            (loss_d, (h_bn, t_bn)), (gh, gt) = jax.value_and_grad(
                d_loss, has_aux=True)((d_heads, d_tail))
            opt_dh, heads_new = self._upd_dh(opt_dh, gh, d_heads)
            opt_dt, tail_new = self._upd_dt(opt_dt, gt, d_tail)
            heads_new = merge_bn(heads_new, h_bn)
            tail_new = merge_bn(tail_new, t_bn)

            def g_loss(gs):
                fake, ng = gen_forward_dict(gs, z.reshape(-1, Z_DIM),
                                            fake_y.reshape(-1), True)
                fake = fake.reshape(k, b, 28, 28, 1)
                logits, _, _ = disc_split(heads_new, tail_new, fake, fake_y, True)
                return gan.g_loss_fn(logits.reshape(-1)), ng

            (loss_g, g_bn), gg = jax.value_and_grad(g_loss, has_aux=True)(g_server)
            opt_gs, g_new = self._upd_gs(opt_gs, gg, g_server)
            g_new = merge_bn(g_new, g_bn)
            return (g_new, heads_new, tail_new,
                    (opt_gs, opt_dh, opt_dt), loss_d, loss_g)

        return step

    def train_steps(self, n: int) -> Dict[str, float]:
        loss_d = loss_g = 0.0
        for _ in range(n):
            b = self.cfg.batch
            imgs, ys = [], []
            for c in self.clients:
                idx = self._rng.integers(0, c.n, b)
                imgs.append(c.images[idx]); ys.append(c.labels[idx])
            z = self._rng.normal(0, 1, (self.K, b, Z_DIM)).astype(np.float32)
            fy = self._rng.integers(0, gan.NUM_CLASSES,
                                    (self.K, b)).astype(np.int32)
            batch = (np.stack(imgs), np.stack(ys), z, fy)
            (self.g_server, self.d_heads, self.d_tail,
             opts, ld, lg) = self._step3(
                self.g_server, self.d_heads, self.d_tail,
                (self.opt_gs, self.opt_dh, self.opt_dt), batch)
            self.opt_gs, self.opt_dh, self.opt_dt = opts
            loss_d, loss_g = float(ld), float(lg)
        return {"loss_d": loss_d, "loss_g": loss_g}

    def federate(self) -> None:
        self.d_heads = fedavg_population(self.d_heads,
                                         self.sizes.astype(np.float64))

    def generate(self, n_per_client_batch: int, labels: np.ndarray):
        gen = jax.jit(lambda gp, z, y: gen_forward_dict(gp, z, y, False)[0])
        out_imgs, out_labs, i = [], [], 0
        while i < len(labels):
            take = min(256, len(labels) - i)
            lab = labels[i: i + take].astype(np.int32)
            z = self._rng.normal(0, 1, (take, Z_DIM)).astype(np.float32)
            out_imgs.append(np.asarray(gen(self.g_server, z, lab)))
            out_labs.append(lab)
            i += take
        return np.concatenate(out_imgs), np.concatenate(out_labs)
