"""Shared infrastructure for the baseline distributed-GAN schemes.

All baselines use the paper's cGAN (Table 3) "to ensure fairness".
The core building block is a *population* of K full local cGANs held as
stacked pytrees and trained with one vmapped jitted step; schemes differ
in what is shared/aggregated and when.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.partition import ClientSpec
from repro.models import gan
from repro.models.gan import Z_DIM
from repro.models.nn import tree_weighted_sum
from repro.optim import adam


@dataclasses.dataclass
class BaselineConfig:
    batch: int = 32
    lr: float = 2e-4
    adam_b1: float = 0.5
    federate_every: int = 5
    seed: int = 0
    steps_per_epoch: Optional[int] = None


def init_population(key, k: int):
    kg, kd = jax.random.split(key)
    gs = jax.vmap(lambda kk: _as_dict(gan.init_generator(kk)))(
        jax.random.split(kg, k))
    ds = jax.vmap(lambda kk: _as_dict(gan.init_discriminator(kk)))(
        jax.random.split(kd, k))
    return gs, ds


def _as_dict(layers: List[Dict]) -> Dict[str, Dict]:
    return {str(i): p for i, p in enumerate(layers)}


def _as_list(d: Dict[str, Dict]) -> List[Dict]:
    return [d[str(i)] for i in range(len(d))]


def gen_forward_dict(params: Dict, z, y, train: bool):
    out, new = gan.generator_forward(_as_list(params), z, y, train=train)
    return out, _as_dict(new)


def disc_forward_dict(params: Dict, img, y, train: bool):
    out, new = gan.discriminator_forward(_as_list(params), img, y, train=train)
    return out, _as_dict(new)


def local_gan_step(g_params, d_params, opt_g, opt_d, batch,
                   opt_update_g, opt_update_d):
    """One cGAN step for a single client (to be vmapped over K)."""
    real_img, real_y, z, fake_y = batch

    def d_loss(dp):
        fake, _ = gen_forward_dict(g_params, z, fake_y, True)
        fake = jax.lax.stop_gradient(fake)
        lr_, nd = disc_forward_dict(dp, real_img, real_y, True)
        lf_, _ = disc_forward_dict(dp, fake, fake_y, True)
        return gan.d_loss_fn(lr_, lf_), nd

    (loss_d, d_bn), grads_d = jax.value_and_grad(d_loss, has_aux=True)(d_params)
    opt_d, d_new = opt_update_d(opt_d, grads_d, d_params)
    d_new = merge_bn(d_new, d_bn)

    def g_loss(gp):
        fake, ng = gen_forward_dict(gp, z, fake_y, True)
        logits, _ = disc_forward_dict(d_new, fake, fake_y, True)
        return gan.g_loss_fn(logits), ng

    (loss_g, g_bn), grads_g = jax.value_and_grad(g_loss, has_aux=True)(g_params)
    opt_g, g_new = opt_update_g(opt_g, grads_g, g_params)
    g_new = merge_bn(g_new, g_bn)
    return g_new, d_new, opt_g, opt_d, loss_d, loss_g


def merge_bn(updated, bn_source):
    flat_u = jax.tree_util.tree_flatten_with_path(updated)[0]
    flat_b = {jax.tree_util.keystr(p): v for p, v in
              jax.tree_util.tree_flatten_with_path(bn_source)[0]}
    out = []
    for path, val in flat_u:
        ks = jax.tree_util.keystr(path)
        out.append(flat_b.get(ks, val)
                   if ks.endswith("['mean']") or ks.endswith("['var']") else val)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(updated), out)


class PopulationTrainer:
    """K independent local cGANs, vmapped. Base class for baselines."""

    name = "population"

    def __init__(self, clients: Sequence[ClientSpec],
                 config: BaselineConfig = BaselineConfig()):
        self.clients = list(clients)
        self.cfg = config
        self.K = len(self.clients)
        self.sizes = np.array([c.n for c in self.clients], np.int64)
        key = jax.random.PRNGKey(config.seed)
        self.g_params, self.d_params = init_population(key, self.K)
        opt_init_g, self._upd_g = adam(config.lr, b1=config.adam_b1)
        opt_init_d, self._upd_d = adam(config.lr, b1=config.adam_b1)
        # per-client optimizer states (vmapped init so `step` is [K])
        self.opt_g = jax.vmap(opt_init_g)(self.g_params)
        self.opt_d = jax.vmap(opt_init_d)(self.d_params)
        self._rng = np.random.default_rng(config.seed + 1)
        self.epoch = 0
        self._step = jax.jit(self._build_step())

    def _build_step(self):
        upd_g, upd_d = self._upd_g, self._upd_d

        def step(g_params, d_params, opt_g, opt_d, batch):
            return jax.vmap(
                lambda gp, dp, og, od, *b: local_gan_step(
                    gp, dp, og, od, b, upd_g, upd_d)
            )(g_params, d_params, opt_g, opt_d, *batch)

        return step

    def _sample_batch(self):
        b = self.cfg.batch
        imgs, ys = [], []
        for c in self.clients:
            idx = self._rng.integers(0, c.n, b)
            imgs.append(c.images[idx])
            ys.append(c.labels[idx])
        z = self._rng.normal(0, 1, (self.K, b, Z_DIM)).astype(np.float32)
        fy = self._rng.integers(0, gan.NUM_CLASSES, (self.K, b)).astype(np.int32)
        return (np.stack(imgs), np.stack(ys), z, fy)

    def train_steps(self, n: int) -> Dict[str, float]:
        loss_d = loss_g = 0.0
        for _ in range(n):
            batch = self._sample_batch()
            (self.g_params, self.d_params, self.opt_g, self.opt_d,
             ld, lg) = self._step(self.g_params, self.d_params,
                                  self.opt_g, self.opt_d, batch)
            loss_d, loss_g = float(ld.mean()), float(lg.mean())
        return {"loss_d": loss_d, "loss_g": loss_g}

    def train_epoch(self) -> Dict[str, float]:
        steps = self.cfg.steps_per_epoch or max(
            1, int(np.median(self.sizes)) // self.cfg.batch)
        m = self.train_steps(steps)
        self.epoch += 1
        if self.epoch % self.cfg.federate_every == 0:
            self.federate()
        return m

    def federate(self) -> None:  # overridden by schemes
        pass

    # -- evaluation ---------------------------------------------------------
    def generate(self, n_per_client_batch: int, labels: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray]:
        gen = jax.jit(lambda gp, z, y: jax.vmap(
            lambda p, zz, yy: gen_forward_dict(p, zz, yy, False)[0]
        )(gp, z, y))
        imgs_all, labs_all = [], []
        i = 0
        while i < len(labels):
            need = min(n_per_client_batch, max(1, -(-(len(labels) - i) // self.K)))
            lab = np.resize(labels[i:], (self.K, need)).astype(np.int32)
            z = self._rng.normal(0, 1, (self.K, need, Z_DIM)).astype(np.float32)
            out = np.asarray(gen(self.g_params, z, lab)).reshape(-1, 28, 28, 1)
            imgs_all.append(out)
            labs_all.append(lab.reshape(-1))
            i += out.shape[0]
        return (np.concatenate(imgs_all)[: len(labels)],
                np.concatenate(labs_all)[: len(labels)])


def fedavg_population(params, weights: np.ndarray):
    """Replace every client copy with the weighted average."""
    w = jnp.asarray(weights / weights.sum())
    avg = tree_weighted_sum(params, w)
    return jax.tree_util.tree_map(
        lambda a, x: jnp.broadcast_to(a, x.shape).astype(x.dtype), avg, params)
