"""PFL-GAN (Wijesinghe et al., 2023) — personalized federated GANs.

Each client trains a full local cGAN. Periodically the server collects
the local generators, synthesizes data from each, embeds it with a
pre-trained encoder, measures pairwise client similarity via KLD of the
embedding distributions, and builds *refined* per-client synthetic
datasets from similar clients. Each client then continues training on
(local real) + (refined synthetic from similar peers).

Note: this shares GAN-generated samples with the server — exactly the
data-sharing weakness Table 1 attributes to it; we reproduce that
behaviour faithfully for comparison.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.common import (BaselineConfig, PopulationTrainer,
                                    gen_forward_dict)
from repro.core.kld import kl_divergence, softmax_np
from repro.models.gan import Z_DIM, NUM_CLASSES


class PFLGANTrainer(PopulationTrainer):
    name = "pfl_gan"

    def __init__(self, clients, config: BaselineConfig = BaselineConfig(),
                 sim_threshold: float = 0.35, synth_per_round: int = 64):
        super().__init__(clients, config)
        self.sim_threshold = sim_threshold
        self.synth_per_round = synth_per_round
        # refined synthetic pools per client
        self._synth_imgs: List[np.ndarray] = [None] * self.K
        self._synth_labs: List[np.ndarray] = [None] * self.K

    def _encode(self, imgs: np.ndarray) -> np.ndarray:
        """Cheap fixed 'pre-trained encoder': downsampled pixel histogram
        embedding (offline stand-in for their pretrained encoder)."""
        pooled = imgs.reshape(imgs.shape[0], 7, 4, 7, 4).mean((2, 4))
        return pooled.reshape(imgs.shape[0], -1)

    def federate(self) -> None:
        n = self.synth_per_round
        # 1. server synthesizes from every client's G
        gen = jax.jit(lambda gp, z, y: jax.vmap(
            lambda p, zz, yy: gen_forward_dict(p, zz, yy, False)[0]
        )(gp, z, y))
        z = self._rng.normal(0, 1, (self.K, n, Z_DIM)).astype(np.float32)
        y = self._rng.integers(0, NUM_CLASSES, (self.K, n)).astype(np.int32)
        synth = np.asarray(gen(self.g_params, z, y))  # [K, n, 28,28,1]
        # 2. embedding distributions + pairwise KLD
        dists = []
        for k in range(self.K):
            emb = self._encode(synth[k])
            dists.append(softmax_np(emb.mean(0)))
        sim = np.zeros((self.K, self.K))
        for i in range(self.K):
            for j in range(self.K):
                if i != j:
                    sim[i, j] = 0.5 * (kl_divergence(dists[i], dists[j])
                                       + kl_divergence(dists[j], dists[i]))
        # 3. refined datasets: pool synthetic data from similar clients
        for k in range(self.K):
            peers = [j for j in range(self.K)
                     if j != k and sim[k, j] < self.sim_threshold]
            if not peers:
                continue
            self._synth_imgs[k] = np.concatenate([synth[j] for j in peers])
            self._synth_labs[k] = np.concatenate([y[j] for j in peers])

    def _sample_batch(self):
        b = self.cfg.batch
        imgs, ys = [], []
        for k, c in enumerate(self.clients):
            if self._synth_imgs[k] is not None and self._rng.random() < 0.3:
                pool_i, pool_l = self._synth_imgs[k], self._synth_labs[k]
                idx = self._rng.integers(0, pool_i.shape[0], b)
                imgs.append(pool_i[idx]); ys.append(pool_l[idx])
            else:
                idx = self._rng.integers(0, c.n, b)
                imgs.append(c.images[idx]); ys.append(c.labels[idx])
        z = self._rng.normal(0, 1, (self.K, b, Z_DIM)).astype(np.float32)
        fy = self._rng.integers(0, NUM_CLASSES, (self.K, b)).astype(np.int32)
        return (np.stack(imgs), np.stack(ys), z, fy)
