"""Generation-quality metrics (paper §5).

* `dataset_score` — the MD-GAN-style Inception-Score analogue using a
  dataset-specific classifier instead of InceptionV3 (paper metric 2a).
* `fid` — Fréchet distance between feature Gaussians (paper metric 2b),
  computed with the eval CNN's penultimate features.
"""
from __future__ import annotations

import numpy as np


def dataset_score(probs: np.ndarray, eps: float = 1e-12) -> float:
    """exp(E_x KL(p(y|x) || p(y))) over classifier predictive probs [N, C]."""
    p_y = probs.mean(0, keepdims=True)
    kl = probs * (np.log(probs + eps) - np.log(p_y + eps))
    return float(np.exp(kl.sum(1).mean()))


def _sqrtm_psd(mat: np.ndarray) -> np.ndarray:
    """Matrix square root of a symmetric PSD matrix via eigendecomposition."""
    w, v = np.linalg.eigh((mat + mat.T) / 2.0)
    w = np.clip(w, 0.0, None)
    return (v * np.sqrt(w)) @ v.T


def fid(feat_real: np.ndarray, feat_fake: np.ndarray) -> float:
    """Fréchet distance between N(mu_r, C_r) and N(mu_f, C_f)."""
    mu_r, mu_f = feat_real.mean(0), feat_fake.mean(0)
    c_r = np.cov(feat_real, rowvar=False)
    c_f = np.cov(feat_fake, rowvar=False)
    diff = mu_r - mu_f
    # trace of the geometric-mean term via sqrt(C_r) C_f sqrt(C_r), PSD-safe
    s_r = _sqrtm_psd(c_r)
    inner = _sqrtm_psd(s_r @ c_f @ s_r)
    return float(diff @ diff + np.trace(c_r) + np.trace(c_f) - 2 * np.trace(inner))
