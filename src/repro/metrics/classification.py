"""Classification metrics with 95% Wald confidence intervals (paper §6).

Macro-averaged one-vs-all Precision / Recall / F1 / FPR, matching the
paper's tables (metric ± Wald CI over the test set size).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


@dataclasses.dataclass
class ClassifierReport:
    accuracy: float
    precision: float
    recall: float
    f1: float
    fpr: float
    ci_accuracy: float
    n: int

    def row(self) -> Dict[str, float]:
        return {"accuracy": self.accuracy, "precision": self.precision,
                "recall": self.recall, "f1": self.f1, "fpr": self.fpr,
                "ci": self.ci_accuracy, "n": self.n}

    def __str__(self):
        pm = self.ci_accuracy * 100
        return (f"acc={self.accuracy*100:.2f}%±{pm:.2f} "
                f"prec={self.precision*100:.2f}% rec={self.recall*100:.2f}% "
                f"f1={self.f1*100:.2f}% fpr={self.fpr*100:.2f}%")


def wald_ci(p: float, n: int, z: float = 1.96) -> float:
    return z * np.sqrt(max(p * (1 - p), 0.0) / max(n, 1))


def evaluate(y_true: np.ndarray, y_pred: np.ndarray,
             num_classes: int = 10) -> ClassifierReport:
    n = y_true.shape[0]
    acc = float((y_true == y_pred).mean())
    precs, recs, f1s, fprs = [], [], [], []
    for c in range(num_classes):
        tp = float(np.sum((y_pred == c) & (y_true == c)))
        fp = float(np.sum((y_pred == c) & (y_true != c)))
        fn = float(np.sum((y_pred != c) & (y_true == c)))
        tn = float(np.sum((y_pred != c) & (y_true != c)))
        if tp + fn == 0:  # class absent from test set
            continue
        prec = tp / (tp + fp) if tp + fp > 0 else 0.0
        rec = tp / (tp + fn)
        f1 = 2 * prec * rec / (prec + rec) if prec + rec > 0 else 0.0
        fpr = fp / (fp + tn) if fp + tn > 0 else 0.0
        precs.append(prec); recs.append(rec); f1s.append(f1); fprs.append(fpr)
    return ClassifierReport(
        accuracy=acc, precision=float(np.mean(precs)), recall=float(np.mean(recs)),
        f1=float(np.mean(f1s)), fpr=float(np.mean(fprs)),
        ci_accuracy=wald_ci(acc, n), n=n)
