from repro.metrics.scores import dataset_score, fid
from repro.metrics.classification import ClassifierReport, evaluate, wald_ci

__all__ = ["dataset_score", "fid", "ClassifierReport", "evaluate", "wald_ci"]
