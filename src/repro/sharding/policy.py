"""Sharding policy: per-parameter PartitionSpecs + activation specs.

Rules are name-based with divisibility-aware fallback: each parameter
kind lists candidate (dim -> mesh axis) placements; an axis is dropped
when it does not evenly divide the dim (e.g. mixtral's 8 experts on a
16-way model axis fall back to TP over d_ff).

Axes:
  * `data` (+ outer `pod` when present) — batch / FSDP axis
  * `model` — tensor-parallel axis

FSDP: when enabled, the non-TP dim of every large matrix additionally
shards over `data`, ZeRO-3 style; XLA GSPMD inserts the per-layer
all-gathers (under `lax.scan` these amortize into one gather per block).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    fsdp: bool = True
    seq_parallel: bool = True       # shard seq over model axis between blocks
    shard_cache_seq: bool = True    # decode KV cache seq axis over model


def data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def sanitize(mesh, shape: Sequence[int], spec: Sequence) -> P:
    """Drop axes that don't divide their dim or don't exist in the mesh."""
    out = []
    names = set(mesh.axis_names)
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        axes = tuple(a for a in axes if a in names)
        size = int(np.prod([dict(mesh.shape)[a] for a in axes])) if axes else 1
        if axes and dim % size == 0 and size > 1:
            out.append(axes[0] if len(axes) == 1 else axes)
        else:
            out.append(None)
    return P(*out)


def client_axes(mesh, n_rows: int):
    """Mesh axes to shard a leading client/population axis over, or None.

    The stacked-client ("rows") axis shards over the data axes
    ('pod', 'data') only when their product evenly divides ``n_rows``
    (sanitize's divisibility fallback) — a ragged split would leave
    shards with unequal row counts, which the federation round's
    shard_map partial-sum cannot express. Returns the sanitize-style
    spec entry: an axis name, a tuple of axis names, or None (no
    sharding — callers fall back to the single-device path).
    """
    return sanitize(mesh, (n_rows,), (data_axes(mesh),))[0]


def group_client_axes(mesh, group_sizes: Sequence[int]):
    """Mesh axes to shard *per-group* client stacks over, or None.

    The chunk-streamed federation round (core/federation.py,
    ``chunk_size=``) scans each profile group's ``[K_g, ...]`` leaf
    stack directly instead of one concatenated ``[K, D]`` buffer, so
    sharding must split every group's rows evenly — a stricter
    condition than ``client_axes``'s total-row divisibility (group
    boundaries may straddle shards in the dense layout, but a shard of
    a *stacked group leaf* cannot hold a ragged row count). Returns
    the common sanitize-style spec entry when every group size
    divides by the data-axes product, else None (callers fall back to
    the unsharded chunk stream).
    """
    specs = {client_axes(mesh, int(s)) for s in group_sizes}
    if len(specs) == 1:
        return specs.pop()
    return None


def cohort_axes(mesh, bucket_sizes: Sequence[int]):
    """Mesh axes to shard bucket-padded serving-cohort rows over, or None.

    The split-serving engine (launch/serve_split.py) pads each cut's
    request rows to a power-of-two bucket (`splitting.bucket_size`)
    before staging them, so — unlike the raw ragged counts
    `group_client_axes` sees during training — the row counts here are
    always powers of two and divide any power-of-two data-axes product
    whenever bucket >= mesh. Same contract as `group_client_axes`: the
    common sanitize-style spec entry when every bucket divides by the
    data-axes product, else None (the engine then runs unsharded).
    """
    specs = {client_axes(mesh, int(b)) for b in bucket_sizes}
    if len(specs) == 1:
        return specs.pop()
    return None


def client_stack_sharding(mesh, shape: Sequence[int]) -> NamedSharding:
    """NamedSharding for a client-stacked ``[K, ...]`` host array: rows
    over the client axes when divisible (``client_axes``), replicated
    on the mesh otherwise. Used to stage `DeviceDataset` rows on the
    fed mesh so the training step and the federation round share one
    device set."""
    axes = client_axes(mesh, int(shape[0]))
    return NamedSharding(mesh, P(axes, *([None] * (len(shape) - 1))))


# parameter-name -> trailing-dims spec (DP = fsdp data axes, MP = model)
# entries use 'DP' / 'MP' placeholders resolved against the mesh.
_PARAM_RULES: Dict[str, Tuple] = {
    # attention projections [D, N, hd] / [N, hd, D]
    "wq": ("DP", "MP", None), "wk": ("DP", "MP", None),
    "wv": ("DP", "MP", None), "w_o": ("DP", "MP", None),
    "wo3": ("MP", None, "DP"),           # attn out  [N, hd, D]
    # dense mlp [D, F] / [F, D]
    "wi2": ("DP", "MP"), "wg2": ("DP", "MP"), "wo2": ("MP", "DP"),
    # moe [E, D, F] / [E, F, D] — expert-parallel preferred, TP fallback
    "wi3": ("MP", "DP", None), "wg3": ("MP", "DP", None),
    "woe": ("MP", None, "DP"),
    "router": (None, None),
    # embeddings [V, D]
    "table": ("MP", "DP"),
    # rg-lru
    "w_in": ("DP", "MP"), "w_gate_x": ("DP", "MP"),
    "w_rec_gate": ("MP", None), "w_in_gate": ("MP", None),
    "lambda": ("MP",), "w_out": ("MP", "DP"),
    # slstm
    "w_z": ("DP", "MP"), "w_i": ("DP", "MP"), "w_f": ("DP", "MP"),
    # mlstm gates [D, N, 2]
    "w_if": ("DP", None, None),
    # generic dense (whisper biases / gan fc)
    "w": ("DP", "MP"), "b": (None,),
    # norms / bn
    "scale": (None,), "bias": (None,), "mean": (None,), "var": (None,),
    # conv kernels (gan): replicated
    "convw": (None, None, None, None),
}


def param_spec(mesh, policy: ShardingPolicy, path: str,
               shape: Sequence[int]) -> P:
    """path: '/'-joined key path; shape: full leaf shape (may include
    leading scan-layer and/or client axes, padded with None)."""
    name = path.split("/")[-1]
    ndim = len(shape)
    # moe weights are [E, D, F]/[E, F, D]; attn wo is [N, hd, D]; dense
    # mlp wi/wo are rank 2 — disambiguate via the path.
    model_size = dict(mesh.shape).get("model", 1)
    if name in ("wi", "wg", "wo") and "moe" in path:
        n_experts = shape[-3]
        ep = n_experts % model_size == 0   # expert-parallel feasible?
        if name in ("wi", "wg"):           # [E, D, F]
            rule = ("MP", "DP", None) if ep else (None, "DP", "MP")
        else:                              # wo [E, F, D]
            rule = ("MP", None, "DP") if ep else (None, "MP", "DP")
    elif name in ("wi", "wg"):
        rule = _PARAM_RULES["wi2"]
    elif name == "wo" and "attn" in path:
        rule = _PARAM_RULES["wo3"]
    elif name == "wo":
        rule = _PARAM_RULES["wo2"]
    else:
        rule = _PARAM_RULES.get(name)
    if rule is None:
        return P()
    rule = tuple(rule)
    # pad leading dims (scan layer axis, stacked client axis) with None
    if len(rule) > ndim:
        return P()
    full = (None,) * (ndim - len(rule)) + rule
    dp = data_axes(mesh) if policy.fsdp else ()
    resolved = []
    for ax in full:
        if ax == "DP":
            resolved.append(dp if len(dp) != 1 else dp[0]) if dp else \
                resolved.append(None)
        elif ax == "MP":
            resolved.append("model" if "model" in mesh.axis_names else None)
        else:
            resolved.append(ax)
    return sanitize(mesh, shape, resolved)


def tree_param_specs(mesh, policy: ShardingPolicy, params) -> Any:
    """PartitionSpec pytree matching `params`."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(_key_name(k) for k in path)
        specs.append(param_spec(mesh, policy, pstr, np.shape(leaf)))
    return jax.tree_util.tree_unflatten(treedef, specs)


def tree_shardings(mesh, policy: ShardingPolicy, params) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        tree_param_specs(mesh, policy, params),
        is_leaf=lambda x: isinstance(x, P))


def _key_name(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


# ---------------------------------------------------------------------------
# activation specs (used via with_sharding_constraint inside the model)
# ---------------------------------------------------------------------------

def act_spec(mesh, policy: ShardingPolicy, kind: str) -> P:
    dp = data_axes(mesh)
    dpa = dp if len(dp) != 1 else dp[0]
    mp = "model" if "model" in mesh.axis_names else None
    if kind == "resid":     # [B, S, D] between blocks
        return P(dpa, mp if policy.seq_parallel else None, None)
    if kind == "resid_inner":
        # [B, S, D] entering attention/ffn: seq gathered, D *replicated*
        # within the model group (Megatron column/row-parallel semantics;
        # constraining D over model here conflicts with the (DP, MP)
        # weight sharding and forces f32 hidden-state gathers — see
        # EXPERIMENTS.md §Perf iteration 8).
        return P(dpa, None, None)
    if kind == "tokens":    # [B, S]
        return P(dpa, None)
    if kind == "cache":     # [B, S, KV, hd]
        return P(dpa, mp if policy.shard_cache_seq else None, None, None)
    if kind == "state":     # [B, R...]
        return P(dpa)
    if kind == "logits":    # [B, S, V]
        return P(dpa, None, mp)
    if kind == "rows":      # [N_rows, ...] population-batch tensors
        return P(dpa)
    return P()


_MESH_STACK: list = []


class activation_sharding:
    """Context manager installing (mesh, policy) for maybe_shard()."""

    def __init__(self, mesh: Optional[Mesh], policy: ShardingPolicy):
        self.pair = (mesh, policy)

    def __enter__(self):
        _MESH_STACK.append(self.pair)
        return self

    def __exit__(self, *exc):
        _MESH_STACK.pop()
        return False


def maybe_shard(x, kind: str):
    if not _MESH_STACK:
        return x
    mesh, policy = _MESH_STACK[-1]
    if mesh is None:
        return x
    spec = act_spec(mesh, policy, kind)
    spec = sanitize(mesh, x.shape, tuple(spec) + (None,) * (x.ndim - len(spec)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
