"""ShapeDtypeStruct input stand-ins for every (arch x input-shape) pair.

Weak-type-correct, shardable, zero allocation. Semantics per family:

 * dense/moe/hybrid/ssm — tokens [B, S] (train/prefill); decode shapes
   supply a single token [B] plus a context-length cache.
 * vlm  — `num_prefix_embeds` patch embeddings [B, P, D] (frontend stub)
   followed by text tokens [B, S-P]; the total context is S.
 * audio (whisper, enc-dec) — encoder frame embeddings [B, S, D] (mel+
   conv stub); decoder tokens bounded by max_target_len. "Sequence
   length" counts encoder frames (the long axis in speech workloads).
 * long_500k on pure full-attention archs uses the sliding-window
   variant (force_window) per DESIGN.md §Decode-shape applicability.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape, INPUT_SHAPES
from repro.models import transformer as T

SWA_FALLBACK_WINDOW = 4096


def decode_window(cfg: ArchConfig, shape: InputShape) -> Optional[int]:
    """force_window to apply for this (arch, shape), None = arch default."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        if cfg.long_context_variant == "swa":
            return SWA_FALLBACK_WINDOW
    return None


def skip_reason(cfg: ArchConfig, shape: InputShape) -> Optional[str]:
    """Return a reason string if this pair is skipped (DESIGN.md notes)."""
    if shape.kind == "decode" and cfg.arch_type == "gan":
        return "GAN has no autoregressive decode step"
    if shape.name == "long_500k":
        if cfg.is_encoder_decoder:
            return ("whisper positional/architectural cap (max 30s windows; "
                    "448-token decoder) — skipped per DESIGN.md")
        if not cfg.supports_long_context and cfg.long_context_variant is None:
            return "pure full attention, no sub-quadratic variant"
    return None


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(cfg: ArchConfig, shape: InputShape
                      ) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.is_encoder_decoder:
        S_dec = cfg.max_target_len
        return {"tokens": _struct((B, S_dec), jnp.int32),
                "labels": _struct((B, S_dec), jnp.int32),
                "enc_frames": _struct((B, S, cfg.d_model), jnp.bfloat16)}
    if cfg.frontend == "vision":
        P = min(cfg.num_prefix_embeds, S // 2)
        return {"tokens": _struct((B, S - P), jnp.int32),
                "labels": _struct((B, S - P), jnp.int32),
                "prefix_embeds": _struct((B, P, cfg.d_model), jnp.bfloat16)}
    return {"tokens": _struct((B, S), jnp.int32),
            "labels": _struct((B, S), jnp.int32)}


def prefill_specs(cfg: ArchConfig, shape: InputShape
                  ) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.is_encoder_decoder:
        return {"tokens": _struct((B, cfg.max_target_len // 2), jnp.int32),
                "enc_frames": _struct((B, S, cfg.d_model), jnp.bfloat16)}
    if cfg.frontend == "vision":
        P = min(cfg.num_prefix_embeds, S // 2)
        return {"tokens": _struct((B, S - P), jnp.int32),
                "prefix_embeds": _struct((B, P, cfg.d_model), jnp.bfloat16)}
    return {"tokens": _struct((B, S), jnp.int32)}


def decode_specs(cfg: ArchConfig, shape: InputShape
                 ) -> Tuple[jax.ShapeDtypeStruct, Any]:
    """Returns (token spec [B], cache struct tree with ctx_len context)."""
    B, S = shape.global_batch, shape.seq_len
    fw = decode_window(cfg, shape)
    if cfg.is_encoder_decoder:
        # self-attn cache bounded by the decoder cap; cross cache = S frames
        def mk():
            c = T.init_cache(cfg, B, cfg.max_target_len)
            for key, entry in c["scanned"].items():
                n_sup = jax.tree_util.tree_leaves(entry)[0].shape[0]
                hd = cfg.resolved_head_dim
                entry["xk"] = jnp.zeros((n_sup, B, S, cfg.n_kv_heads, hd),
                                        cfg.dtype)
                entry["xv"] = jnp.zeros((n_sup, B, S, cfg.n_kv_heads, hd),
                                        cfg.dtype)
            return c
        cache = jax.eval_shape(mk)
    else:
        cache = jax.eval_shape(
            lambda: T.init_cache(cfg, B, S, force_window=fw))
    return _struct((B,), jnp.int32), cache


def input_specs(cfg: ArchConfig, shape_name: str) -> Dict[str, Any]:
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        return {"kind": "train", "batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"kind": "prefill", "batch": prefill_specs(cfg, shape)}
    token, cache = decode_specs(cfg, shape)
    return {"kind": "decode", "token": token, "cache": cache,
            "force_window": decode_window(cfg, shape)}
