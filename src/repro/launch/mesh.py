"""Production mesh definitions (TPU v5e).

Single pod: (data=16, model=16) = 256 chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; `pod` is an outer
data-parallel axis (DCN-connected).

`make_production_mesh` is a function (never a module-level constant) so
importing this module touches no jax device state — required because
the dry-run forces 512 host devices while tests must see 1.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU tests/examples (no sharding)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_federation_mesh(n_devices: int | None = None):
    """1-D ``('data',)`` mesh over the first ``n_devices`` visible
    devices, for client-axis-sharded federation rounds
    (core/federation.py, mesh= argument).

    Usable on forced-multi-device CPU: a process started with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` can build
    federation meshes of 1/2/4/8 devices from the same pool (the tests
    and the sharded bench section do exactly this). ``None`` takes
    every visible device.
    """
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devs):
        raise ValueError(f"requested {n} devices, have {len(devs)} "
                         "(force more with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    return Mesh(np.asarray(devs[:n]), ("data",))


def forced_device_env(n_devices: int, pythonpath_prepend=()):
    """Subprocess environment that forces ``n_devices`` host CPU
    devices — the one shared recipe behind the multi-device test
    harness (tests/conftest.py ``multihost``) and the sharded bench
    workers (benchmarks/federation_bench.py).

    Replaces any existing ``--xla_force_host_platform_device_count``
    rather than prepending (the last duplicate wins XLA's flag
    parsing), and pins ``JAX_PLATFORMS=cpu`` so a GPU/TPU host still
    gives the child the forced CPU pool the flag describes. Entries in
    ``pythonpath_prepend`` go ahead of the inherited PYTHONPATH.
    """
    import os

    env = dict(os.environ)
    keep = [f for f in env.get("XLA_FLAGS", "").split()
            if "--xla_force_host_platform_device_count" not in f]
    env["XLA_FLAGS"] = " ".join(
        [f"--xla_force_host_platform_device_count={int(n_devices)}"] + keep)
    env["JAX_PLATFORMS"] = "cpu"
    if pythonpath_prepend:
        prev = env.get("PYTHONPATH", "")
        env["PYTHONPATH"] = os.pathsep.join(
            list(pythonpath_prepend) + ([prev] if prev else []))
    return env


# hardware constants (TPU v5e) for the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
CHIP_HBM_BYTES = 16 * 1024 ** 3
