"""Production mesh definitions (TPU v5e).

Single pod: (data=16, model=16) = 256 chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; `pod` is an outer
data-parallel axis (DCN-connected).

`make_production_mesh` is a function (never a module-level constant) so
importing this module touches no jax device state — required because
the dry-run forces 512 host devices while tests must see 1.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU tests/examples (no sharding)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# hardware constants (TPU v5e) for the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
CHIP_HBM_BYTES = 16 * 1024 ** 3
