"""Multi-pod dry-run: lower + compile every (arch x input-shape) pair on
the production mesh, with zero device allocation (ShapeDtypeStruct).

MUST be the very first two lines (jax locks device count on first init):
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import re
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.launch import mesh as mesh_mod
from repro.launch.input_specs import input_specs, skip_reason, decode_window
from repro.models import transformer as T
from repro.optim import adam
from repro.sharding.policy import (ShardingPolicy, activation_sharding,
                                   data_axes, sanitize, tree_param_specs)

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\b")
TYPE_RE = re.compile(r"\b([a-z]?[a-z0-9]+)\[([0-9,]*)\]")
DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes_from_hlo(hlo: str) -> Dict[str, float]:
    """Sum per-device result bytes of every collective op in optimized HLO."""
    totals: Dict[str, float] = {}
    for line in hlo.splitlines():
        m = COLLECTIVE_RE.search(line)
        if m is None or "=" not in line:
            continue
        kind = m.group(1)
        lhs = line.split("=", 1)[1]
        lhs = lhs.split(m.group(0))[0]  # types before the op name
        nbytes = 0.0
        for dt, dims in TYPE_RE.findall(lhs):
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        if nbytes:
            totals[kind] = totals.get(kind, 0.0) + nbytes
    totals["total"] = sum(v for k, v in totals.items() if k != "total")
    return totals


def _batch_shardings(mesh, policy, batch_specs):
    dp = data_axes(mesh)
    dpa = dp if len(dp) != 1 else dp[0]
    out = {}
    for k, v in batch_specs.items():
        spec = (dpa,) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, sanitize(mesh, v.shape, spec))
    return out


def _cache_shardings(mesh, policy, cache_struct):
    dp = data_axes(mesh)
    dpa = dp if len(dp) != 1 else dp[0]
    mp = "model" if policy.shard_cache_seq else None

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = leaf.shape
        if name in ("k", "v", "xk", "xv"):
            if len(shape) == 5:   # [n_super, B, S, KV, hd]
                s = (None, dpa, mp, None, None)
            else:                 # [B, S, KV, hd]
                s = (dpa, mp, None, None)
        elif name == "length":
            s = ()
        elif len(shape) >= 2:     # recurrent states [n_super?, B, ...]
            s = ((None, dpa) if len(shape) > 2 else (dpa,)) + \
                (None,) * (len(shape) - (2 if len(shape) > 2 else 1))
        else:
            s = (None,) * len(shape)
        return NamedSharding(mesh, sanitize(mesh, shape, s))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_struct)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat])


def _shardings_of_specs(mesh, specs_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs_tree,
        is_leaf=lambda x: isinstance(x, P))


def build_lowered(arch: str, shape_name: str, *, multi_pod: bool,
                  policy: Optional[ShardingPolicy] = None,
                  donate: bool = True, cfg_override=None, unroll: int = 1):
    """Returns (lowered, meta). Raises on skip (caller checks skip_reason)."""
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    policy = policy or ShardingPolicy()
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    spec = input_specs(cfg, shape_name)
    key = jax.random.PRNGKey(0)

    params_struct = jax.eval_shape(lambda: T.init_lm(key, cfg))
    param_specs = tree_param_specs(mesh, policy, params_struct)
    params_shardings = _shardings_of_specs(mesh, param_specs)

    with mesh, activation_sharding(mesh, policy):
        if spec["kind"] == "train":
            optimizer = adam(1e-4, grad_clip=1.0)
            train_step, opt_init = T.make_train_step(cfg, optimizer,
                                                     unroll=unroll)
            opt_struct = jax.eval_shape(opt_init, params_struct)
            opt_shardings = jax.tree_util.tree_map(
                lambda l: NamedSharding(mesh, P()) if l.ndim == 0 else None,
                opt_struct)
            # mu/nu mirror the param shardings
            opt_shardings = type(opt_struct)(
                step=NamedSharding(mesh, P()),
                mu=params_shardings, nu=params_shardings)
            batch_shardings = _batch_shardings(mesh, policy, spec["batch"])
            fn = jax.jit(train_step,
                         in_shardings=(params_shardings, opt_shardings,
                                       batch_shardings),
                         donate_argnums=(0, 1) if donate else ())
            lowered = fn.lower(params_struct, opt_struct, spec["batch"])
        elif spec["kind"] == "prefill":
            def prefill_fn(params, batch):
                return T.prefill(cfg, params, batch["tokens"],
                                 prefix_embeds=batch.get("prefix_embeds"),
                                 enc_frames=batch.get("enc_frames"),
                                 unroll=unroll)
            batch_shardings = _batch_shardings(mesh, policy, spec["batch"])
            fn = jax.jit(prefill_fn,
                         in_shardings=(params_shardings, batch_shardings))
            lowered = fn.lower(params_struct, spec["batch"])
        else:  # decode
            fw = spec["force_window"]

            def serve_step(params, token, cache):
                return T.decode_step(cfg, params, token, cache,
                                     force_window=fw, unroll=unroll)
            dp = data_axes(mesh)
            dpa = dp if len(dp) != 1 else dp[0]
            tok_sh = NamedSharding(mesh, sanitize(
                mesh, spec["token"].shape, (dpa,)))
            cache_sh = _cache_shardings(mesh, policy, spec["cache"])
            fn = jax.jit(serve_step,
                         in_shardings=(params_shardings, tok_sh, cache_sh),
                         donate_argnums=(2,) if donate else ())
            lowered = fn.lower(params_struct, spec["token"], spec["cache"])

    meta = {"arch": arch, "shape": shape_name,
            "multi_pod": multi_pod, "kind": spec["kind"],
            "chips": int(np.prod(list(dict(mesh.shape).values()))),
            "params": cfg.param_count()}
    return lowered, meta


def analyze(lowered, meta: Dict[str, Any]) -> Dict[str, Any]:
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    out = dict(meta, compile_s=round(compile_s, 1))
    try:
        ma = compiled.memory_analysis()
        out["memory"] = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(ma, "peak_memory_in_bytes", 0) or
                              getattr(ma, "temp_size_in_bytes", 0)),
        }
    except Exception as e:  # pragma: no cover
        out["memory"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        out["cost"] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
    except Exception as e:  # pragma: no cover
        out["cost"] = {"error": str(e)}
    hlo = compiled.as_text()
    out["collectives"] = collective_bytes_from_hlo(hlo)
    out["hlo_bytes"] = len(hlo)
    return out


def _cost_tuple(res: Dict[str, Any]) -> Dict[str, float]:
    return {"flops": res["cost"].get("flops", 0.0),
            "bytes": res["cost"].get("bytes_accessed", 0.0),
            "coll": res["collectives"].get("total", 0.0)}


def calibrate_scan_costs(arch: str, shape_name: str, multi_pod: bool,
                         policy: Optional[ShardingPolicy],
                         res: Dict[str, Any]) -> None:
    """XLA cost_analysis counts a lax.scan body ONCE (trip count is
    invisible to the HLO cost model), so scanned-transformer flops /
    bytes / collective totals underestimate by ~n_super. Calibrate with
    a depth-2 twin lowered both scanned (counts 1 body) and unrolled
    (counts 2): body = unrolled - scanned; corrected = full + (n_super-1)
    * body. Adds 'cost_corrected' / 'collectives_corrected' in place."""
    import dataclasses
    cfg = get_config(arch)
    pat = len(cfg.block_pattern)
    n_super = cfg.n_layers // pat
    if n_super < 2:
        res["cost_corrected"] = _cost_tuple(res)
        res["scan_correction"] = 1.0
        return
    kw = dict(n_layers=2 * pat)
    if cfg.is_encoder_decoder:
        kw["n_enc_layers"] = 2
    cfg2 = dataclasses.replace(cfg, **kw)
    rs = analyze(*build_lowered(arch, shape_name, multi_pod=multi_pod,
                                policy=policy, cfg_override=cfg2, unroll=1))
    ru = analyze(*build_lowered(arch, shape_name, multi_pod=multi_pod,
                                policy=policy, cfg_override=cfg2, unroll=2))
    full = _cost_tuple(res)
    body = {k: max(0.0, _cost_tuple(ru)[k] - _cost_tuple(rs)[k])
            for k in full}
    # enc and dec scans share the body delta; both scale by ~n_super
    corrected = {k: full[k] + (n_super - 1) * body[k] for k in full}
    res["cost_corrected"] = corrected
    res["scan_body"] = body
    res["scan_correction"] = (corrected["flops"] /
                              max(full["flops"], 1.0))


def run_pair(arch: str, shape_name: str, multi_pod: bool,
             policy: Optional[ShardingPolicy] = None,
             calibrate: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    reason = skip_reason(cfg, INPUT_SHAPES[shape_name])
    if reason:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "skipped": reason}
    lowered, meta = build_lowered(arch, shape_name, multi_pod=multi_pod,
                                  policy=policy)
    res = analyze(lowered, meta)
    if calibrate:
        try:
            calibrate_scan_costs(arch, shape_name, multi_pod, policy, res)
        except Exception as e:  # calibration is best-effort
            res["calibration_error"] = f"{type(e).__name__}: {e}"
    if INPUT_SHAPES[shape_name].name == "long_500k" and \
            decode_window(cfg, INPUT_SHAPES[shape_name]):
        res["variant"] = "swa"
    return res


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--no-cache-shard", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args(argv)

    policy = ShardingPolicy(fsdp=not args.no_fsdp,
                            seq_parallel=not args.no_seq_parallel,
                            shard_cache_seq=not args.no_cache_shard)
    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(INPUT_SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'2pod' if mp else '1pod'}"
                t0 = time.time()
                try:
                    res = run_pair(arch, shape, mp, policy)
                except Exception as e:
                    res = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "error": f"{type(e).__name__}: {e}"}
                res["wall_s"] = round(time.time() - t0, 1)
                results.append(res)
                status = ("SKIP " + res["skipped"] if "skipped" in res else
                          "ERROR " + res.get("error", "")[:200]
                          if "error" in res else
                          f"ok flops={res['cost'].get('flops', 0):.3e} "
                          f"coll={res['collectives'].get('total', 0):.3e}B "
                          f"peak={res['memory'].get('peak_bytes', 0)/2**30:.2f}GiB")
                print(f"[dryrun] {tag}: {status} ({res['wall_s']}s)",
                      flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(res) + "\n")
    errs = [r for r in results if "error" in r]
    if errs:
        sys.exit(1)


if __name__ == "__main__":
    main()
