"""Training launcher.

Two modes:
  * huscf (default for --arch huscf-gan): the paper's split-federated
    GAN over a heterogeneous client population.
  * centralized: standard data+tensor-parallel LM training on synthetic
    token streams for any assigned --arch (smoke-scale on CPU; the full
    configs are exercised via dryrun.py).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch huscf-gan \
      --scenario 2dom_noniid --clients 8 --epochs 4
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --smoke --steps 20
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def train_huscf_gan(args) -> None:
    from repro.core import HuSCFConfig, HuSCFTrainer, PAPER_DEVICES
    from repro.data import build_scenario
    from repro.checkpoint import save_checkpoint
    from repro.launch.mesh import make_federation_mesh

    clients = build_scenario(args.scenario, num_clients=args.clients,
                             base_size=args.base_size, seed=args.seed)
    devices = [PAPER_DEVICES[i % 7] for i in range(args.clients)]
    # one mesh for the whole trainer: the device-resident dataset rows
    # and the federation buffer shard over the same client axis
    # (make_federation_mesh is the single factory for both; a 1-device
    # pool runs the unsharded path).
    n_dev = args.fed_devices or jax.device_count()
    fed_mesh = make_federation_mesh(n_dev) if n_dev > 1 else None
    tr = HuSCFTrainer(clients, devices,
                      config=HuSCFConfig(batch=args.batch,
                                         federate_every=args.federate_every,
                                         seed=args.seed,
                                         use_kernel=args.use_kernel,
                                         fused_epoch=not args.per_step,
                                         cohort_size=args.cohort,
                                         agg_chunk=args.agg_chunk,
                                         reoptimize_every=args.reoptimize_every),
                      fed_mesh=fed_mesh)
    agg = (f"chunked({args.agg_chunk})" if args.agg_chunk else "dense")
    part = (f"cohort {args.cohort}/{args.clients}" if args.cohort
            else "full participation")
    reopt = (f", re-cut every {args.reoptimize_every} rounds"
             if args.reoptimize_every else "")
    print(f"[train] GA latency model: {tr.ga_latency:.2f}s/iter, "
          f"{len(tr.groups)} profile groups, "
          f"mesh={n_dev if fed_mesh is not None else 1}dev, "
          f"{'per-step' if args.per_step else 'fused'} epochs, "
          f"{agg} aggregation, {part}{reopt}")
    for ep in range(args.epochs):
        t0 = time.time()
        m = tr.train_epoch()
        print(f"[train] epoch {ep + 1}: loss_d={m['loss_d']:.3f} "
              f"loss_g={m['loss_g']:.3f} ({time.time() - t0:.1f}s)",
              flush=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, tr.state, step=tr.epoch)
        print(f"[train] checkpoint -> {args.ckpt}")


def train_lm(args) -> None:
    from repro.configs import get_config, get_smoke_config
    from repro.data import lm_batches
    from repro.models import transformer as T
    from repro.optim import adam, warmup_cosine
    from repro.checkpoint import save_checkpoint

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = T.init_lm(key, cfg)
    opt = adam(warmup_cosine(args.lr, 10, max(args.steps, 20)),
               grad_clip=1.0)
    train_step, opt_init = T.make_train_step(cfg, opt)
    opt_state = opt_init(params)
    step = jax.jit(train_step)
    gen = lm_batches(cfg.vocab, args.batch, args.seq, seed=args.seed)
    for i in range(args.steps):
        toks, labs = next(gen)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labs)}
        if cfg.frontend == "vision":
            rng = np.random.default_rng(i)
            batch["prefix_embeds"] = jnp.asarray(rng.normal(
                0, 1, (args.batch, cfg.num_prefix_embeds, cfg.d_model)),
                dtype=jnp.float32)
        if cfg.is_encoder_decoder:
            rng = np.random.default_rng(i)
            batch["enc_frames"] = jnp.asarray(rng.normal(
                0, 1, (args.batch, cfg.num_prefix_embeds, cfg.d_model)),
                dtype=jnp.float32)
            batch["tokens"] = batch["tokens"][:, : cfg.max_target_len]
            batch["labels"] = batch["labels"][:, : cfg.max_target_len]
        t0 = time.time()
        params, opt_state, m = step(params, opt_state, batch)
        if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
            print(f"[train] step {i}: loss={float(m['loss']):.4f} "
                  f"({time.time() - t0:.2f}s)", flush=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, params, step=args.steps)
        print(f"[train] checkpoint -> {args.ckpt}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--scenario", default="2dom_noniid")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--base-size", type=int, default=128)
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--federate-every", type=int, default=2)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="Pallas weighted_agg for federation")
    ap.add_argument("--fed-devices", type=int, default=None,
                    help="client-axis mesh size shared by the training "
                         "step and federation (default: every visible "
                         "device; 1 disables sharding)")
    ap.add_argument("--per-step", action="store_true",
                    help="per-step oracle loop instead of scan-fused "
                         "device-resident epochs")
    ap.add_argument("--cohort", type=int, default=None,
                    help="sample this many clients per federation round "
                         "(default: full participation)")
    ap.add_argument("--agg-chunk", type=int, default=None,
                    help="stream aggregation in client chunks of this "
                         "size instead of the dense [K, D] buffer")
    ap.add_argument("--reoptimize-every", type=int, default=None,
                    help="re-run the fused GA cut search every N "
                         "federation rounds; strictly better cuts "
                         "regroup the population online (default: "
                         "static cuts)")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)
    if args.arch == "huscf-gan":
        train_huscf_gan(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
