"""Paper-technique dry-run: lower+compile the HuSCF split-federated
training steps on the production mesh.

Two subjects:
  * huscf-gan       — the paper's cGAN with 256 clients over the paper's
                      7 device profiles, 4 cuts each (GA-assigned),
                      client populations sharded along the data axis.
  * huscf-lm:<arch> — the §7.3 extension: 2-cut U-shaped split of an
                      assigned LM with per-profile client stacks.

Run:  python -m repro.launch.dryrun_paper [--multi-pod] [--lm granite-3-2b]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.genetic import GAConfig, optimize_cuts
from repro.core.huscf import build_net_apply, _merge_bn
from repro.core.latency import PAPER_DEVICES, PAPER_SERVER
from repro.core.splitting import group_by_profile
from repro.core import split_transformer as ST
from repro.launch import mesh as mesh_mod
from repro.launch.dryrun import analyze, collective_bytes_from_hlo
from repro.models import gan
from repro.models.gan import DISC_LAYER_DEFS, GEN_LAYER_DEFS, Z_DIM
from repro.optim import adam
from repro.sharding.policy import (ShardingPolicy, activation_sharding,
                                   data_axes, sanitize)


def _dp(mesh):
    dp = data_axes(mesh)
    return dp if len(dp) != 1 else dp[0]


def build_gan_population(n_clients: int = 224, batch: int = 64):
    """GA-assigned cuts for the client population over the paper's 7
    profiles. Clients are laid out profile-contiguously with equal
    per-profile counts so every stacked client axis is divisible by the
    (pod x) data mesh axes — otherwise `sanitize` must drop the sharding
    and the population silently replicates (measured: 0 collective
    bytes, every chip computing all clients)."""
    per = max(32, n_clients // 7 // 32 * 32)
    devices = [PAPER_DEVICES[p] for p in range(7) for _ in range(per)]
    res = optimize_cuts(devices, PAPER_SERVER, batch=batch,
                        config=GAConfig(population_size=60, generations=10,
                                        seed=0))
    groups = group_by_profile(devices, res.cuts)
    return groups, res


def _stack_struct(init_fn, k):
    return jax.eval_shape(
        lambda: jax.vmap(lambda kk: init_fn(kk, jnp.float32))(
            jax.random.split(jax.random.PRNGKey(0), k)))


def gan_state_struct(groups):
    """ShapeDtypeStruct state mirroring HuSCFTrainer._init_state."""
    from repro.core.splitting import server_union_span
    n_g, n_d = len(GEN_LAYER_DEFS), len(DISC_LAYER_DEFS)
    server_g = {str(l): jax.eval_shape(
        lambda l=l: GEN_LAYER_DEFS[l][0](jax.random.PRNGKey(0), jnp.float32))
        for l in server_union_span(groups, "G", n_g)}
    server_d = {str(l): jax.eval_shape(
        lambda l=l: DISC_LAYER_DEFS[l][0](jax.random.PRNGKey(0), jnp.float32))
        for l in server_union_span(groups, "D", n_d)}
    client_g, client_d = {}, {}
    for g in groups:
        gh, gt = g.cut.g_h, g.cut.g_t
        dh, dt = g.cut.d_h, g.cut.d_t
        client_g[g.name] = {str(l): _stack_struct(GEN_LAYER_DEFS[l][0], g.size)
                            for l in list(range(gh)) + list(range(gt, n_g))}
        client_d[g.name] = {str(l): _stack_struct(DISC_LAYER_DEFS[l][0], g.size)
                            for l in list(range(dh)) + list(range(dt, n_d))}
    g_params = {"client": client_g, "server": server_g}
    d_params = {"client": client_d, "server": server_d}
    opt_init_g, _ = adam(2e-4)
    opt_init_d, _ = adam(2e-4)
    return {"G": g_params, "D": d_params,
            "opt_g": jax.eval_shape(opt_init_g, g_params),
            "opt_d": jax.eval_shape(opt_init_d, d_params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def build_gan_step(groups, batch: int, concat_groups: bool = True,
                   return_mids: bool = False):
    """One HuSCF-GAN train step (same math as HuSCFTrainer's step core).
    concat_groups=False is the beyond-paper no-concat server schedule.
    return_mids additionally returns the per-group middle-activation
    batch means (the scan-fused epoch's EMA input)."""
    gen_apply = build_net_apply(groups, "G", concat_groups=concat_groups)
    disc_apply = build_net_apply(groups, "D", capture_middle=True,
                                 concat_groups=concat_groups)
    total_clients = sum(g.size for g in groups)
    _, upd_g = adam(2e-4)
    _, upd_d = adam(2e-4)

    def mean_client_loss(logits, target):
        tot = 0.0
        for g in groups:
            tot = tot + gan.bce_logits(logits[g.name].reshape(-1),
                                       target) * g.size
        return tot / total_clients

    def step(state, batch_in):
        g_params, d_params = state["G"], state["D"]

        def d_loss(d_p):
            fake, _, _, _ = gen_apply(
                g_params["client"], g_params["server"],
                {g.name: (batch_in["z"][g.name], batch_in["fy"][g.name])
                 for g in groups}, True)
            fake = {k: jax.lax.stop_gradient(v) for k, v in fake.items()}
            lr_, ncr, nsr, mids = disc_apply(
                d_p["client"], d_p["server"],
                {g.name: (batch_in["img"][g.name], batch_in["y"][g.name])
                 for g in groups}, True)
            lf_, _, _, _ = disc_apply(
                d_p["client"], d_p["server"],
                {g.name: (fake[g.name], batch_in["fy"][g.name])
                 for g in groups}, True)
            return (mean_client_loss(lr_, 1.0) + mean_client_loss(lf_, 0.0),
                    ({"client": ncr, "server": nsr}, mids))

        (loss_d, (d_bn, mids)), grads_d = jax.value_and_grad(
            d_loss, has_aux=True)(d_params)
        opt_d, d_new = upd_d(state["opt_d"], grads_d, d_params)
        d_new = _merge_bn(d_new, d_bn)

        def g_loss(g_p):
            fake, ncg, nsg, _ = gen_apply(
                g_p["client"], g_p["server"],
                {g.name: (batch_in["z"][g.name], batch_in["fy"][g.name])
                 for g in groups}, True)
            logits, _, _, _ = disc_apply(
                d_new["client"], d_new["server"],
                {g.name: (fake[g.name], batch_in["fy"][g.name])
                 for g in groups}, True)
            return mean_client_loss(logits, 1.0), {"client": ncg,
                                                   "server": nsg}

        (loss_g, g_bn), grads_g = jax.value_and_grad(
            g_loss, has_aux=True)(g_params)
        opt_g, g_new = upd_g(state["opt_g"], grads_g, g_params)
        g_new = _merge_bn(g_new, g_bn)
        new_state = {"G": g_new, "D": d_new, "opt_g": opt_g, "opt_d": opt_d,
                     "step": state["step"] + 1}
        metrics = {"loss_d": loss_d, "loss_g": loss_g}
        if return_mids:
            return new_state, metrics, mids
        return new_state, metrics

    return step


def build_gan_epoch(groups, batch: int, n_steps: int,
                    concat_groups: bool = True):
    """Scan-fused device-resident epoch (DESIGN.md §Device-resident
    epochs) on dry-run structs: per-step on-device sampling from a
    staged DeviceDataset plus the in-carry [K, F] middle-activation
    EMA, `n_steps` steps in one dispatch. The scan body is the shared
    `huscf.make_epoch_fn` — the lowering cannot drift from the trainer."""
    from repro.core.huscf import make_epoch_fn
    from repro.data.pipeline import sample_batch
    from repro.models.gan import NUM_CLASSES

    step = build_gan_step(groups, batch, concat_groups=concat_groups,
                          return_mids=True)

    def step_core(state, drawn):
        return step(state, {"img": drawn["real_img"], "y": drawn["real_y"],
                            "z": drawn["z"], "fy": drawn["fake_y"]})

    def sample(dataset, key):
        return sample_batch(dataset, key, batch=batch, z_dim=Z_DIM,
                            num_classes=NUM_CLASSES)

    return make_epoch_fn(groups, step_core, sample, n_steps)


def gan_dataset_struct(groups, n_rows: int = 600):
    """ShapeDtypeStruct DeviceDataset (padded client rows)."""
    from repro.data.pipeline import DeviceDataset
    images = {g.name: jax.ShapeDtypeStruct((g.size, n_rows, 28, 28, 1),
                                           jnp.float32) for g in groups}
    labels = {g.name: jax.ShapeDtypeStruct((g.size, n_rows), jnp.int32)
              for g in groups}
    counts = {g.name: jax.ShapeDtypeStruct((g.size,), jnp.int32)
              for g in groups}
    return DeviceDataset(tuple(g.name for g in groups), images, labels,
                         counts)


def gan_batch_struct(groups, batch, act_dtype=jnp.float32):
    out = {"img": {}, "y": {}, "z": {}, "fy": {}}
    for g in groups:
        out["img"][g.name] = jax.ShapeDtypeStruct(
            (g.size, batch, 28, 28, 1), act_dtype)
        out["y"][g.name] = jax.ShapeDtypeStruct((g.size, batch), jnp.int32)
        out["z"][g.name] = jax.ShapeDtypeStruct((g.size, batch, Z_DIM),
                                                act_dtype)
        out["fy"][g.name] = jax.ShapeDtypeStruct((g.size, batch), jnp.int32)
    return out


def _client_shardings(mesh, tree):
    """Shard every stacked-client leading axis over the data axes."""
    dpa = _dp(mesh)

    def sh(leaf):
        spec = (dpa,) + (None,) * (len(leaf.shape) - 1)
        return NamedSharding(mesh, sanitize(mesh, leaf.shape, spec))
    return jax.tree_util.tree_map(sh, tree)


def run_gan(multi_pod: bool, n_clients: int = 224, batch: int = 64,
            concat_groups: bool = True, bf16_acts: bool = False,
            scan_steps: int = 0) -> Dict[str, Any]:
    """scan_steps > 0 lowers the scan-fused device-resident epoch
    (on-device sampling + EMA carry) instead of one training step."""
    if scan_steps > 0 and bf16_acts:
        # the epoch samples its batches on device (f32, trainer
        # parity); a silent f32 lowering must not masquerade as bf16
        raise ValueError("--bf16 is not supported with --scan-steps: "
                         "the device-resident epoch stages/samples f32")
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    groups, ga = build_gan_population(n_clients, batch)
    state = gan_state_struct(groups)

    # shardings: client stacks + batch over data; server params replicated
    # (they are small convs) — the activations concat over clients*batch
    # shards over data via the inputs.
    state_sh = jax.tree_util.tree_map(lambda _: None, state)
    state_sh = {
        "G": {"client": _client_shardings(mesh, state["G"]["client"]),
              "server": jax.tree_util.tree_map(
                  lambda _: NamedSharding(mesh, P()), state["G"]["server"])},
        "D": {"client": _client_shardings(mesh, state["D"]["client"]),
              "server": jax.tree_util.tree_map(
                  lambda _: NamedSharding(mesh, P()), state["D"]["server"])},
        "opt_g": None, "opt_d": None,
        "step": NamedSharding(mesh, P()),
    }
    # opt states mirror the param shardings
    state_sh["opt_g"] = type(state["opt_g"])(
        step=NamedSharding(mesh, P()), mu=state_sh["G"], nu=state_sh["G"])
    state_sh["opt_d"] = type(state["opt_d"])(
        step=NamedSharding(mesh, P()), mu=state_sh["D"], nu=state_sh["D"])
    policy = ShardingPolicy()
    with mesh, activation_sharding(mesh, policy):
        if scan_steps > 0:
            from repro.models.gan import DISC_MIDDLE_FEATURES
            from repro.sharding.policy import client_stack_sharding
            K = sum(g.size for g in groups)
            epoch = build_gan_epoch(groups, batch, scan_steps,
                                    concat_groups=concat_groups)
            ds = gan_dataset_struct(groups)
            ds_sh = jax.tree_util.tree_map(
                lambda l: client_stack_sharding(mesh, l.shape), ds)
            rep = NamedSharding(mesh, P())
            key_s = jax.ShapeDtypeStruct((2,), jnp.uint32)
            ema_s = jax.ShapeDtypeStruct((K, DISC_MIDDLE_FEATURES),
                                         jnp.float32)
            init_s = jax.ShapeDtypeStruct((), jnp.bool_)
            fn = jax.jit(epoch,
                         in_shardings=(state_sh, ds_sh, rep,
                                       client_stack_sharding(mesh,
                                                             ema_s.shape),
                                       rep),
                         donate_argnums=(0, 3))
            lowered = fn.lower(state, ds, key_s, ema_s, init_s)
            shape_name = f"epoch{scan_steps}_b{batch}_K{n_clients}"
        else:
            batch_struct = gan_batch_struct(
                groups, batch, jnp.bfloat16 if bf16_acts else jnp.float32)
            batch_sh = _client_shardings(mesh, batch_struct)
            step = build_gan_step(groups, batch,
                                  concat_groups=concat_groups)
            fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,))
            lowered = fn.lower(state, batch_struct)
            shape_name = f"train_b{batch}_K{n_clients}"
    meta = {"arch": "huscf-gan", "shape": shape_name,
            "multi_pod": multi_pod, "kind": "paper-train",
            "chips": int(np.prod(list(dict(mesh.shape).values()))),
            "params": 3_018_182, "ga_latency_model_s": ga.latency,
            "variant": ("paper-concat" if concat_groups else "no-concat")
            + ("+bf16" if bf16_acts else "")}
    return analyze(lowered, meta)


def run_lm(arch: str, multi_pod: bool, *, seq: int = 1024,
           per_client_batch: int = 2, n_weak: int = 32, n_strong: int = 32
           ) -> Dict[str, Any]:
    cfg = get_config(arch)
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    groups = ST.default_groups(cfg, n_weak=n_weak, n_strong=n_strong)
    params = jax.eval_shape(
        lambda: ST.init_split_lm(jax.random.PRNGKey(0), cfg, groups))
    step, opt_init = ST.make_split_train_step(cfg, groups)
    opt = jax.eval_shape(opt_init, params)
    batch = {
        "tokens": {g.name: jax.ShapeDtypeStruct(
            (g.n_clients, per_client_batch, seq), jnp.int32) for g in groups},
        "labels": {g.name: jax.ShapeDtypeStruct(
            (g.n_clients, per_client_batch, seq), jnp.int32) for g in groups},
    }
    # server trunk: standard TP+FSDP rules; clients: stacked axis over
    # data, embedding tables additionally vocab-sharded over model
    from repro.sharding.policy import tree_param_specs
    policy0 = ShardingPolicy()
    server_specs = tree_param_specs(mesh, policy0, params["server"])
    server_sh = jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, sp), server_specs,
        is_leaf=lambda x: isinstance(x, P))
    dpa = _dp(mesh)

    def client_leaf_sh(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name == "table":      # [K, V, D]
            spec = (dpa, "model", None)
        else:
            spec = (dpa,) + (None,) * (len(leaf.shape) - 1)
        return NamedSharding(mesh, sanitize(mesh, leaf.shape, spec))

    flat, treedef = jax.tree_util.tree_flatten_with_path(params["clients"])
    clients_sh = jax.tree_util.tree_unflatten(
        treedef, [client_leaf_sh(pth, l) for pth, l in flat])
    params_sh = {"server": server_sh, "clients": clients_sh}
    opt_sh = type(opt)(step=NamedSharding(mesh, P()),
                       mu=params_sh, nu=params_sh)
    batch_sh = _client_shardings(mesh, batch)
    policy = ShardingPolicy()
    with mesh, activation_sharding(mesh, policy):
        fn = jax.jit(step, in_shardings=(params_sh, opt_sh, batch_sh),
                     donate_argnums=(0, 1))
        lowered = fn.lower(params, opt, batch)
    meta = {"arch": f"huscf-lm:{arch}", "shape": f"split_train_s{seq}",
            "multi_pod": multi_pod, "kind": "paper-train",
            "chips": int(np.prod(list(dict(mesh.shape).values()))),
            "params": cfg.param_count()}
    return analyze(lowered, meta)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--lm", default=None,
                    help="also dry-run the split-LM for this arch")
    ap.add_argument("--skip-gan", action="store_true")
    ap.add_argument("--no-concat", action="store_true",
                    help="beyond-paper per-group server schedule")
    ap.add_argument("--bf16", action="store_true",
                    help="bf16 activations (beyond-paper)")
    ap.add_argument("--scan-steps", type=int, default=0,
                    help="lower a scan-fused device-resident epoch of N "
                         "steps (on-device sampling + EMA carry) instead "
                         "of a single step")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for mp in meshes:
        if not args.skip_gan:
            t0 = time.time()
            res = run_gan(mp, concat_groups=not args.no_concat,
                          bf16_acts=args.bf16, scan_steps=args.scan_steps)
            res["wall_s"] = round(time.time() - t0, 1)
            results.append(res)
            print(f"[paper-dryrun] huscf-gan x {'2pod' if mp else '1pod'}: "
                  f"flops={res['cost'].get('flops', 0):.3e} "
                  f"coll={res['collectives'].get('total', 0):.3e}B "
                  f"peak={res['memory'].get('peak_bytes', 0)/2**30:.2f}GiB "
                  f"({res['wall_s']}s)", flush=True)
        if args.lm:
            t0 = time.time()
            res = run_lm(args.lm, mp)
            res["wall_s"] = round(time.time() - t0, 1)
            results.append(res)
            print(f"[paper-dryrun] huscf-lm:{args.lm} x "
                  f"{'2pod' if mp else '1pod'}: "
                  f"flops={res['cost'].get('flops', 0):.3e} "
                  f"coll={res['collectives'].get('total', 0):.3e}B "
                  f"peak={res['memory'].get('peak_bytes', 0)/2**30:.2f}GiB "
                  f"({res['wall_s']}s)", flush=True)
    if args.out:
        with open(args.out, "a") as f:
            for r in results:
                f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()
