"""Executable U-shaped split-serving engine (DESIGN.md §SplitProgram).

Training, the analytic latency model, and serving now execute ONE
compiled `SplitProgram` (core/segments.py). This launcher is the third
consumer: it serves inference requests over the trained split cGAN with
the exact schedule the paper trains under — each request's head runs on
its client's personal weights, the server batches every cut's uplinked
activations per layer (the Eq. 7 join), and the tail returns to the
client — instead of gathering full models to one place (which the
paper's data-sharing constraints forbid: clients never hold the middle
layers, the server never holds the heads/tails).

Engine mechanics:

* Requests are grouped by the owning client's profile group (= cut).
  Each group's request rows pad to a power-of-two bucket
  (`splitting.bucket_size`), so a churning request mix lands on a small
  set of compiled shapes: the jitted executor is cached per
  (active groups, buckets) signature and replayed across calls.
* The executor IS `segments.make_apply` in eval mode over the
  subprogram compiled from the *active* groups only — if no request
  touches a cut, its join barrier and (possibly) server layers drop out
  of the schedule, exactly as `compile_split_program` derives.
  Eval-mode BatchNorm is per-element, so bucket-padding rows cannot
  perturb valid rows.
* The analytic side of the same program (`program_forward_latency`,
  Eq. 7 + Eq. 9 with no backward) predicts the serving latency for the
  executed cohort — `counts=` carries the padded per-cut request
  multiplicities — which `benchmarks/serve_bench.py` compares against
  measured wall-clock per profile mix.

The LM decode tail (`--mode lm`) applies the same U-shape to an
autoregressive transformer: client-owned bottom/top blocks wrap a
server trunk, the server trunk's prefill runs the Pallas
memory-efficient attention kernel (`ops.mem_attention`) and its decode
runs `ops.flash_decode`, and the whole generation loop is one jitted
`lax.scan` (no host round-trips, same shape as launch/serve.py).

  PYTHONPATH=src python -m repro.launch.serve_split --mode gan \
      --mix edge-heavy --requests 24
  PYTHONPATH=src python -m repro.launch.serve_split --mode lm \
      --batch 2 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, List, Mapping, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.latency import Cut, DeviceProfile, PAPER_DEVICES, PAPER_SERVER
from repro.core.segments import (SplitProgram, compile_split_program,
                                 make_apply, program_forward_latency)
from repro.core.splitting import (ProfileGroup, bucket_size,
                                  group_by_profile, server_union_span)
from repro.kernels import ops, ref
from repro.models import attention as A
from repro.models import nn
from repro.models.gan import GEN_LAYER_DEFS, DISC_LAYER_DEFS, Z_DIM
from repro.sharding.policy import (ShardingPolicy, activation_sharding,
                                   cohort_axes)

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# GAN split serving
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeRequest:
    """One generation request: which client it belongs to (that client's
    personal head/tail weights serve it) plus the conditional inputs."""
    client_id: int
    z: np.ndarray          # [Z_DIM] latent
    y: int                 # class label


class SplitGanEngine:
    """Batched split-cGAN inference over a heterogeneous population.

    ``client_params`` / ``server_params`` use the trainer's layout
    (``state["G"]["client"]`` / ``["server"]``): per-group dicts of
    client-stacked layer trees, and the server's union-span layers.
    """

    def __init__(self, groups: Sequence[ProfileGroup],
                 client_params: Dict[str, Dict[str, Any]],
                 server_params: Dict[str, Any], net: str = "G",
                 mesh=None, policy: Optional[ShardingPolicy] = None):
        self.groups = list(groups)
        self.net = net
        self.client_params = client_params
        self.server_params = server_params
        self.mesh = mesh
        self.policy = policy or ShardingPolicy()
        self._row_of: Dict[int, Tuple[str, int]] = {}
        for g in self.groups:
            for row, cid in enumerate(g.client_ids):
                self._row_of[cid] = (g.name, row)
        self._group_of = {g.name: g for g in self.groups}
        self._programs: Dict[Tuple[str, ...], SplitProgram] = {}
        self._fns: Dict[Tuple, Any] = {}

    # -- program / executor caches -----------------------------------------
    def program_for(self, active: Tuple[str, ...]) -> SplitProgram:
        """Subprogram over the active groups only: absent cuts drop
        their join barriers (and possibly whole server layers) from the
        schedule — serving executes/bills only work that is present."""
        if active not in self._programs:
            subset = [self._group_of[n] for n in active]
            self._programs[active] = compile_split_program(subset, self.net)
        return self._programs[active]

    def _fn(self, active: Tuple[str, ...], buckets: Tuple[int, ...]):
        key = (active, buckets)
        if key in self._fns:
            return self._fns[key]
        apply = make_apply(self.program_for(active))

        def run(client_params, server_params, rows, z, y):
            # gather each request's personal client weights by row index
            # (traced — one compiled program serves any member mix)
            gathered = {
                g: jax.tree_util.tree_map(
                    lambda x: jnp.take(x, rows[g], axis=0),
                    client_params[g])
                for g in active}
            inputs = {g: (z[g][:, None, :], y[g][:, None]) for g in active}
            out, _, _, _ = apply(gathered, server_params, inputs, False)
            return {g: out[g][:, 0] for g in active}

        fn = jax.jit(run)
        self._fns[key] = fn
        return fn

    # -- serving -------------------------------------------------------------
    def plan(self, requests: Sequence[ServeRequest]
             ) -> Tuple[Tuple[str, ...], Tuple[int, ...], Dict[str, List[int]]]:
        """(active group names, buckets, per-group request indices)."""
        per: Dict[str, List[int]] = {}
        for i, r in enumerate(requests):
            gname, _ = self._row_of[r.client_id]
            per.setdefault(gname, []).append(i)
        active = tuple(g.name for g in self.groups if g.name in per)
        buckets = tuple(bucket_size(len(per[g])) for g in active)
        return active, buckets, per

    def serve(self, requests: Sequence[ServeRequest]) -> np.ndarray:
        """Run the cohort through the U-shaped program; [N, 28, 28, 1]
        images in request order."""
        active, buckets, per = self.plan(requests)
        fn = self._fn(active, buckets)
        rows, z, y = {}, {}, {}
        for g, bkt in zip(active, buckets):
            idxs = per[g]
            n = len(idxs)
            r = np.zeros(bkt, np.int32)
            zz = np.zeros((bkt, Z_DIM), np.float32)
            yy = np.zeros(bkt, np.int32)
            for j, i in enumerate(idxs):
                req = requests[i]
                r[j] = self._row_of[req.client_id][1]
                zz[j] = req.z
                yy[j] = req.y
            # bucket-padding rows replay request 0's operands (row 0 /
            # zeros) — eval-mode BN is per-element so they cannot touch
            # valid rows; they are sliced off below.
            rows[g] = jnp.asarray(r)
            z[g] = jnp.asarray(zz)
            y[g] = jnp.asarray(yy)
        axes = cohort_axes(self.mesh, buckets) if self.mesh is not None \
            else None
        if axes is not None:
            with activation_sharding(self.mesh, self.policy):
                out = fn(self.client_params, self.server_params, rows, z, y)
        else:
            out = fn(self.client_params, self.server_params, rows, z, y)
        out = {g: np.asarray(v) for g, v in out.items()}
        imgs = np.zeros((len(requests),) + out[active[0]].shape[1:],
                        out[active[0]].dtype)
        for g in active:
            for j, i in enumerate(per[g]):
                imgs[i] = out[g][j]
        return imgs

    def predict_latency(self, requests: Sequence[ServeRequest],
                        server: DeviceProfile = PAPER_SERVER,
                        padded: bool = True) -> float:
        """Analytic Eq. 7/9 forward latency for this cohort from the
        same program the executor runs. ``padded=True`` bills the
        bucket-padded multiplicities (what actually executes);
        ``False`` bills only the real requests (the padding overhead is
        the ratio of the two)."""
        active, buckets, per = self.plan(requests)
        program = self.program_for(active)
        profiles = {g: self._group_of[g].profile for g in active}
        counts = {g: float(b) if padded else float(len(per[g]))
                  for g, b in zip(active, buckets)}
        return program_forward_latency(program, profiles, server,
                                       batch=1, counts=counts)


def init_gan_serving_state(key, groups: Sequence[ProfileGroup],
                           net: str = "G"
                           ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Random split-cGAN weights in the trainer's state layout (the
    engine normally loads a trained `HuSCFTrainer` state; the launcher
    and benchmarks serve from random weights — latency is
    weight-independent)."""
    defs = GEN_LAYER_DEFS if net == "G" else DISC_LAYER_DEFS
    n = len(defs)
    key, ks = jax.random.split(key)
    server = {}
    for l in server_union_span(groups, net, n):
        ks, sub = jax.random.split(ks)
        server[str(l)] = defs[l][0](sub, jnp.float32)
    client = {}
    for g in groups:
        key, sub = jax.random.split(key)
        h, t = (g.cut.g_h, g.cut.g_t) if net == "G" else (g.cut.d_h, g.cut.d_t)
        keys = jax.random.split(sub, g.size)
        client[g.name] = {
            str(l): jax.vmap(lambda kk, l=l: defs[l][0](kk, jnp.float32))(keys)
            for l in list(range(h)) + list(range(t, n))}
    return client, server


# Two heterogeneous profile mixes (paper Table 4 devices): name ->
# list of (device, cut, n_clients). Weak devices delegate almost
# everything (head 1 / tail 4); strong devices keep two layers per side.
SERVE_MIXES: Dict[str, List[Tuple[DeviceProfile, Cut, int]]] = {
    "edge-heavy": [
        (PAPER_DEVICES[0], Cut(1, 4, 1, 4), 4),   # device1, weakest
        (PAPER_DEVICES[4], Cut(1, 4, 1, 4), 3),   # device5
        (PAPER_DEVICES[1], Cut(2, 3, 2, 3), 2),   # device2
    ],
    "balanced": [
        (PAPER_DEVICES[1], Cut(1, 4, 1, 4), 2),   # device2
        (PAPER_DEVICES[3], Cut(2, 4, 1, 4), 2),   # device4
        (PAPER_DEVICES[2], Cut(2, 3, 2, 3), 2),   # device3
        (PAPER_DEVICES[6], Cut(2, 3, 2, 3), 2),   # device7
    ],
}


def build_mix(mix: str) -> List[ProfileGroup]:
    devices, cuts = [], []
    for dev, cut, n in SERVE_MIXES[mix]:
        devices += [dev] * n
        cuts += [cut] * n
    return group_by_profile(devices, cuts)


# ---------------------------------------------------------------------------
# LM split decode tail — U-shaped transformer serving on the Pallas kernels
# ---------------------------------------------------------------------------

class SplitLMConfig(NamedTuple):
    """A compact decoder-only LM split client-head / server-trunk /
    client-tail: blocks [0, head_end) and [tail_start, n_layers) stay on
    the client, [head_end, tail_start) run on the server with the
    Pallas attention kernels."""
    vocab: int = 256
    d_model: int = 64
    n_layers: int = 4
    n_heads: int = 4
    n_kv: int = 2
    head_dim: int = 16
    d_ff: int = 128
    head_end: int = 1
    tail_start: int = 3
    s_max: int = 160

    def is_server(self, l: int) -> bool:
        return self.head_end <= l < self.tail_start


def _lm_block_init(key, cfg: SplitLMConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": nn.rmsnorm_init(cfg.d_model),
        "attn": A.attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv,
                            cfg.head_dim),
        "ln2": nn.rmsnorm_init(cfg.d_model),
        "wi": nn.dense_init(k2, cfg.d_model, cfg.d_ff),
        "wo": nn.dense_init(k3, cfg.d_ff, cfg.d_model),
    }


def init_split_lm(key, cfg: SplitLMConfig):
    keys = jax.random.split(key, cfg.n_layers + 1)
    embed = jax.random.normal(keys[0], (cfg.vocab, cfg.d_model),
                              jnp.float32) * 0.02
    return {"embed": embed,
            "blocks": [_lm_block_init(keys[l + 1], cfg)
                       for l in range(cfg.n_layers)],
            "norm_f": nn.rmsnorm_init(cfg.d_model)}


def _lm_mlp(p, x):
    return nn.dense_apply(p["wo"], jax.nn.gelu(nn.dense_apply(p["wi"], x)))


def _lm_block_prefill(cfg: SplitLMConfig, p, x, positions, lens,
                      server: bool):
    """One block over the whole prompt; returns (y, (k, v)) for the
    cache. Server blocks run the Pallas memory-efficient kernel; client
    blocks (tiny head/tail segments) use the dense reference."""
    h = nn.rmsnorm_apply(p["ln1"], x)
    q, k, v = A.qkv_proj(p["attn"], h)
    q = A.apply_rope(q, positions)
    k = A.apply_rope(k, positions)
    if server:
        o = ops.mem_attention(q, k, v, lens, causal=True)
    else:
        o = ref.mem_attention_ref(q, k, v, lens, causal=True)
    x = x + A.out_proj(p["attn"], o)
    return x + _lm_mlp(p, nn.rmsnorm_apply(p["ln2"], x)), (k, v)


def _lm_block_decode(cfg: SplitLMConfig, p, x, ck, cv, t, server: bool):
    """One block for one token at traced position ``t``; appends to the
    [B, s_max, KV, hd] caches in place (dynamic_update_slice on the
    scan carry). Server blocks attend with the flash_decode kernel."""
    h = nn.rmsnorm_apply(p["ln1"], x)
    q, k, v = A.qkv_proj(p["attn"], h)              # [B, 1, N, hd]
    pos = t[None] if t.ndim == 0 else t
    q = A.apply_rope(q, pos)
    k = A.apply_rope(k, pos)
    ck = jax.lax.dynamic_update_slice(ck, k, (0, t, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v, (0, t, 0, 0))
    if server:
        o = ops.flash_decode(q[:, 0], ck, cv, t + 1)[:, None]
    else:
        o = ref.flash_decode_ref(q[:, 0], ck, cv, t + 1)[:, None]
    x = x + A.out_proj(p["attn"], o)
    return x + _lm_mlp(p, nn.rmsnorm_apply(p["ln2"], x)), ck, cv


def split_lm_prefill(cfg: SplitLMConfig, params, tokens):
    """U-shaped prefill: client head blocks -> server trunk (Pallas
    mem_attention) -> client tail blocks. Returns (last-position logits
    [B, V], caches tuple)."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(S)
    lens = jnp.full((B,), S, jnp.int32)
    caches = []
    for l, blk in enumerate(params["blocks"]):
        x, (k, v) = _lm_block_prefill(cfg, blk, x, positions, lens,
                                      cfg.is_server(l))
        ck = jnp.zeros((B, cfg.s_max, cfg.n_kv, cfg.head_dim), k.dtype)
        caches.append((jax.lax.dynamic_update_slice(ck, k, (0, 0, 0, 0)),
                       jax.lax.dynamic_update_slice(ck, v, (0, 0, 0, 0))))
    x = nn.rmsnorm_apply(params["norm_f"], x[:, -1])
    return x @ params["embed"].T, tuple(caches)


def _lm_step(cfg: SplitLMConfig, params, cur, caches, t):
    """One decode token through the U-shape; returns (logits [B, V],
    new caches)."""
    x = params["embed"][cur][:, None, :]
    new = []
    for l, blk in enumerate(params["blocks"]):
        ck, cv = caches[l]
        x, ck, cv = _lm_block_decode(cfg, blk, x, ck, cv, t,
                                     cfg.is_server(l))
        new.append((ck, cv))
    x = nn.rmsnorm_apply(params["norm_f"], x[:, 0])
    return x @ params["embed"].T, tuple(new)


def split_lm_generate(cfg: SplitLMConfig, params, tokens, n_gen: int):
    """Greedy generation, the whole decode tail one jitted lax.scan
    (serve.py's shape): returns [B, n_gen] generated tokens."""
    logits, caches = split_lm_prefill(cfg, params, tokens)
    cur0 = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = jnp.int32(tokens.shape[1])

    def body(carry, _):
        cur, caches, t = carry
        logits, caches = _lm_step(cfg, params, cur, caches, t)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        return (nxt, caches, t + 1), nxt

    _, ys = jax.lax.scan(body, (cur0, caches, t0), None, length=n_gen - 1)
    return jnp.concatenate([cur0[:, None], ys.T], axis=1)


def split_lm_decode_logits(cfg: SplitLMConfig, params, tokens,
                           prompt_len: int):
    """Teacher-forced per-step decode logits for tokens[:, prompt_len:]
    (the engine-vs-monolithic equivalence probe): [B, S - prompt_len, V]
    where slot i holds the logits emitted *after* consuming
    tokens[:, prompt_len + i - 1] (slot 0 comes from the prefill)."""
    logits0, caches = split_lm_prefill(cfg, params, tokens[:, :prompt_len])
    t0 = jnp.int32(prompt_len)
    feed = tokens[:, prompt_len:-1].T                  # [S-p-1, B]

    def body(carry, cur):
        caches, t = carry
        logits, caches = _lm_step(cfg, params, cur, caches, t)
        return (caches, t + 1), logits

    _, ys = jax.lax.scan(body, (caches, t0), feed)
    return jnp.concatenate([logits0[:, None], ys.transpose(1, 0, 2)], axis=1)


def lm_reference_logits(cfg: SplitLMConfig, params, tokens):
    """Monolithic dense-attention forward over the full sequence (no
    split, no kernels, no caches) — the oracle the U-shaped engine must
    match: [B, S, V]."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(S)
    lens = jnp.full((B,), S, jnp.int32)
    for blk in params["blocks"]:
        x, _ = _lm_block_prefill(cfg, blk, x, positions, lens, server=False)
    x = nn.rmsnorm_apply(params["norm_f"], x)
    return x @ params["embed"].T


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _run_gan(args) -> None:
    groups = build_mix(args.mix)
    key = jax.random.PRNGKey(args.seed)
    client, server = init_gan_serving_state(key, groups)
    engine = SplitGanEngine(groups, client, server)
    rng = np.random.default_rng(args.seed)
    n_clients = sum(g.size for g in groups)
    reqs = [ServeRequest(int(rng.integers(0, n_clients)),
                         rng.normal(0, 1, Z_DIM).astype(np.float32),
                         int(rng.integers(0, 10)))
            for _ in range(args.requests)]
    active, buckets, per = engine.plan(reqs)
    print(f"[serve_split] mix={args.mix} requests={len(reqs)} "
          f"active_cuts={len(active)} buckets={list(buckets)}")
    engine.serve(reqs)                       # compile + warm
    t0 = time.time()
    for _ in range(args.iters):
        imgs = engine.serve(reqs)
    measured = (time.time() - t0) / args.iters
    analytic = engine.predict_latency(reqs)
    print(f"[serve_split] images={imgs.shape} "
          f"measured={measured * 1e3:.1f}ms analytic={analytic * 1e3:.2f}ms "
          f"ratio={measured / analytic:.2f}")


def _run_lm(args) -> None:
    cfg = SplitLMConfig(s_max=args.prompt_len + args.gen + 16)
    key = jax.random.PRNGKey(args.seed)
    params = init_split_lm(key, cfg)
    rng = np.random.default_rng(args.seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab,
                                      (args.batch, args.prompt_len)),
                         dtype=jnp.int32)
    gen = jax.jit(lambda p, t: split_lm_generate(cfg, p, t, args.gen))
    toks = np.asarray(jax.block_until_ready(gen(params, tokens)))  # warm
    t0 = time.time()
    toks = np.asarray(jax.block_until_ready(gen(params, tokens)))
    dt = time.time() - t0
    print(f"[serve_split] lm decode {args.batch}x{args.gen} "
          f"(server blocks [{cfg.head_end},{cfg.tail_start}) on Pallas "
          f"kernels): {dt:.2f}s "
          f"({args.batch * args.gen / max(dt, 1e-9):.0f} tok/s)")
    print(f"[serve_split] sample continuation (seq 0): "
          f"{toks[0][:16].tolist()}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", choices=("gan", "lm"), default="gan")
    ap.add_argument("--mix", choices=sorted(SERVE_MIXES), default="edge-heavy")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.mode == "gan":
        _run_gan(args)
    else:
        _run_lm(args)


if __name__ == "__main__":
    main()
