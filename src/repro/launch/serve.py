"""Serving launcher: batched prefill + autoregressive decode for any
assigned --arch (smoke-scale on CPU).

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-350m --smoke \
      --batch 4 --prompt-len 64 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.federation import donate_default
from repro.models import transformer as T


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = T.init_lm(key, cfg)
    rng = np.random.default_rng(args.seed)
    kwargs = {}
    if cfg.is_encoder_decoder:
        kwargs["enc_frames"] = jnp.asarray(rng.normal(
            0, 1, (args.batch, cfg.num_prefix_embeds, cfg.d_model)),
            dtype=jnp.float32)
    elif cfg.frontend == "vision":
        kwargs["prefix_embeds"] = jnp.asarray(rng.normal(
            0, 1, (args.batch, cfg.num_prefix_embeds, cfg.d_model)),
            dtype=jnp.float32)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                       (args.batch, args.prompt_len)),
                          dtype=jnp.int32)

    t0 = time.time()
    prefill = jax.jit(lambda p, t: T.prefill(
        cfg, p, t, margin=args.gen + 16, **kwargs))
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"[serve] prefill {args.batch}x{args.prompt_len}: "
          f"{t_prefill:.2f}s ({args.batch * args.prompt_len / t_prefill:.0f} "
          f"tok/s)")

    # The whole greedy/sampled decode tail is one jitted lax.scan: the
    # old loop round-tripped to host every token (np.asarray per step)
    # and re-dispatched decode_step gen-1 times. The KV cache rides the
    # scan carry and is donated into the call where the backend can
    # alias it (donate_default: TPU/GPU only — CPU XLA ignores it).
    def decode_tail(p, cur0, cache, key):
        def body(carry, _):
            cur, cache, key = carry
            logits, cache = T.decode_step(cfg, p, cur, cache)
            if args.temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(
                    sub, logits / args.temperature).astype(jnp.int32)
            else:
                nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            return (nxt, cache, key), nxt

        _, ys = jax.lax.scan(body, (cur0, cache, key), None,
                             length=args.gen - 1)
        return jnp.concatenate([cur0[:, None], ys.T], axis=1)

    decode_fn = jax.jit(
        decode_tail, donate_argnums=(2,) if donate_default() else ())
    cur0 = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.time()
    toks_dev = decode_fn(params, cur0, cache, key)
    jax.block_until_ready(toks_dev)
    t_dec = time.time() - t0
    toks = np.asarray(toks_dev)
    print(f"[serve] decoded {args.gen} tokens/seq: {t_dec:.2f}s "
          f"({args.batch * max(args.gen - 1, 1) / max(t_dec, 1e-9):.0f} tok/s)")
    print(f"[serve] sample continuation (seq 0): {toks[0][:16].tolist()}")


if __name__ == "__main__":
    main()
