"""Roofline analysis from dry-run artifacts (deliverable g).

Reads the JSONL written by launch/dryrun.py and derives, per
(arch x shape x mesh):

    compute_s    = HLO_FLOPs_per_device / peak_FLOPs(chip)
    memory_s     = HLO_bytes_per_device / HBM_bw(chip)
    collective_s = collective_bytes_per_device / ICI_link_bw

(cost_analysis of the SPMD-partitioned module is per device, so the
"chips x" normalization of the spec is already applied.)

Also reports MODEL_FLOPS = 6 N_active D_tokens (train) or 2 N_active
D_tokens (inference) vs HLO FLOPs — the useful-compute ratio that
exposes remat/dispatch waste — and names the dominant term.
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List, Optional

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, CHIP_HBM_BYTES


def model_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / chips


def analyze_record(rec: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if "skipped" in rec or "error" in rec:
        return None
    if "cost_corrected" in rec:   # scan-trip-count calibrated (see dryrun)
        flops = rec["cost_corrected"]["flops"]
        bytes_acc = rec["cost_corrected"]["bytes"]
        coll = rec["cost_corrected"]["coll"]
    else:
        flops = rec["cost"].get("flops", 0.0)
        bytes_acc = rec["cost"].get("bytes_accessed", 0.0)
        coll = rec["collectives"].get("total", 0.0)
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_acc / HBM_BW
    collective_s = coll / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], rec["chips"])
    useful = mf / flops if flops else 0.0
    peak = rec.get("memory", {}).get("peak_bytes", 0)
    return dict(rec, **terms, dominant=dominant,
                model_flops=mf, useful_ratio=useful,
                bound_s=max(terms.values()),
                fits_hbm=bool(peak <= CHIP_HBM_BYTES),
                hbm_frac=peak / CHIP_HBM_BYTES)


def what_would_help(row: Dict[str, Any]) -> str:
    d = row["dominant"]
    if d == "collective_s":
        return ("reduce resharding: fewer FSDP gathers / keep residents "
                "sharded; overlap collectives with compute")
    if d == "memory_s":
        if row["kind"] == "decode":
            return "decode is cache-streaming bound: shrink/quantize KV cache"
        return "recompute less / fuse more; raise arithmetic intensity"
    if row["useful_ratio"] < 0.4:
        return "compute-bound but wasteful: cut remat or MoE over-capacity"
    return "near compute roofline: only larger per-chip batch helps"


def load(path: str) -> List[Dict[str, Any]]:
    rows = []
    with open(path) as f:
        for line in f:
            rec = json.loads(line)
            rows.append(rec)
    # dedup keeping the latest record per key
    best = {}
    for r in rows:
        best[(r["arch"], r["shape"], r["multi_pod"])] = r
    return list(best.values())


def markdown_table(rows: List[Dict[str, Any]], multi_pod: bool = False
                   ) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | HBM frac | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["multi_pod"] != multi_pod:
            continue
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped |"
                       f" — | — | {r['skipped'][:60]} |")
            continue
        a = analyze_record(r)
        if a is None:
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | "
                       f"{r.get('error','')[:60]} |")
            continue
        out.append(
            f"| {a['arch']} | {a['shape']} | {a['compute_s']:.2e} | "
            f"{a['memory_s']:.2e} | {a['collective_s']:.2e} | "
            f"{a['dominant'].replace('_s','')} | {a['useful_ratio']:.2f} | "
            f"{a['hbm_frac']:.2f} | {what_would_help(a)[:70]} |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)
    rows = load(args.path)
    print(markdown_table(rows, args.multi_pod))


if __name__ == "__main__":
    main()
