"""IBM Granite 3.0 2B dense GQA [hf:ibm-granite/granite-3.0-2b-base]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b", arch_type="dense", n_layers=40, d_model=2048,
    n_heads=32, n_kv_heads=8, d_ff=8192, vocab=49155, head_dim=64,
    mlp_variant="swiglu", tie_embeddings=True,
    long_context_variant="swa",
    citation="hf:ibm-granite/granite-3.0-2b-base")


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=256, vocab=256, param_dtype="float32")
