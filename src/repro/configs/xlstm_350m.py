"""xLSTM-350M — alternating sLSTM and mLSTM blocks [arXiv:2405.04517]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", arch_type="ssm", n_layers=24, d_model=1024,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304, head_dim=256,
    block_pattern=("slstm", "mlstm"), d_rnn=2048,
    tie_embeddings=True, supports_long_context=True,
    citation="arXiv:2405.04517",
    notes="d_ff=0: xLSTM blocks carry their own up/down projections. "
          "Attention-free; long_500k decodes with O(1) state.")


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
        head_dim=64, d_rnn=256, vocab=256, param_dtype="float32")
