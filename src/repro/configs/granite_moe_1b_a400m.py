"""IBM Granite 3.0 1B-A400M — 32-expert top-8 fine-grained MoE
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", arch_type="moe", n_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=8, d_ff=512, vocab=49155,
    head_dim=64, n_experts=32, moe_top_k=8, mlp_variant="swiglu",
    tie_embeddings=True, long_context_variant="swa",
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
    notes="32 experts divide the 16-way model axis -> expert-parallel "
          "sharding (2 experts/chip) with GSPMD all-to-all.")


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=64, vocab=256, n_experts=4, moe_top_k=2,
        param_dtype="float32")
