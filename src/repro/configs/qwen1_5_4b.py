"""Qwen1.5-4B — dense GQA with QKV bias [hf:Qwen/Qwen1.5-0.5B]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b", arch_type="dense", n_layers=40, d_model=2560,
    n_heads=20, n_kv_heads=20, d_ff=6912, vocab=151936, head_dim=128,
    qkv_bias=True, mlp_variant="swiglu", tie_embeddings=True,
    long_context_variant="swa",
    citation="hf:Qwen/Qwen1.5-0.5B")


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        head_dim=32, d_ff=256, vocab=256, param_dtype="float32")
