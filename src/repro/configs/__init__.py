from repro.configs.base import (ArchConfig, InputShape, INPUT_SHAPES,
                                get_config, get_smoke_config, list_archs)

__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "get_config",
           "get_smoke_config", "list_archs"]
