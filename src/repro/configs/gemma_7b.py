"""Gemma-7B — GeGLU MLP, head_dim 256 [arXiv:2403.08295]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b", arch_type="dense", n_layers=28, d_model=3072,
    n_heads=16, n_kv_heads=16, d_ff=24576, vocab=256000, head_dim=256,
    mlp_variant="geglu", tie_embeddings=True,
    long_context_variant="swa",
    citation="arXiv:2403.08295",
    notes="MHA on 7b (kv=16); the 2b sibling uses MQA. GeGLU FFN, "
          "256k vocab dominates memory -> vocab sharded over model axis.")


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        head_dim=32, d_ff=512, vocab=512, param_dtype="float32")
