"""RecurrentGemma-2B (Griffin) — RG-LRU recurrent blocks + local
attention in a 2:1 pattern [arXiv:2402.19427]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", arch_type="hybrid", n_layers=26,
    d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680, vocab=256000,
    head_dim=256, mlp_variant="geglu",
    block_pattern=("rglru", "rglru", "local_attn"), local_window=2048,
    d_rnn=2560, tie_embeddings=True, supports_long_context=True,
    citation="arXiv:2402.19427",
    notes="1 local-attn : 2 RG-LRU blocks (Griffin). MQA (kv=1). "
          "long_500k decodes with O(1) recurrent state + 2048 window.")


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=128, n_heads=4, n_kv_heads=1,
        head_dim=32, d_ff=256, vocab=256, d_rnn=128, local_window=32,
        param_dtype="float32")
