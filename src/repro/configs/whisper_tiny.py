"""Whisper-tiny — encoder-decoder with conv/mel frontend stubbed
[arXiv:2212.04356]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", arch_type="audio", n_layers=4, d_model=384,
    n_heads=6, n_kv_heads=6, d_ff=1536, vocab=51865, head_dim=64,
    mlp_variant="gelu", norm="layernorm", dense_bias=True,
    is_encoder_decoder=True, n_enc_layers=4, max_target_len=448,
    frontend="audio", num_prefix_embeds=1500,  # 30s @ 50 frames/s
    tie_embeddings=True, rope_theta=10000.0,
    citation="arXiv:2212.04356",
    notes="Mel+conv frontend stubbed: input_specs() supplies frame "
          "embeddings [B, frames, d_model]. Decoder self-attn uses "
          "absolute positions bounded by max_target_len=448; long_500k "
          "skipped (architectural position cap, see DESIGN.md).")


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, n_enc_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, head_dim=32, d_ff=256, vocab=256,
        num_prefix_embeds=32, max_target_len=64, param_dtype="float32")
