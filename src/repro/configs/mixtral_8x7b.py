"""Mixtral 8x7B — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", arch_type="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000, head_dim=128,
    n_experts=8, moe_top_k=2, sliding_window=4096, mlp_variant="swiglu",
    rope_theta=1e6, tie_embeddings=False,
    supports_long_context=True,   # SWA bounds the KV cache
    citation="arXiv:2401.04088",
    notes="SWA window 4096 per the paper; experts TP-sharded over d_ff "
          "(8 experts do not divide the 16-way model axis).")


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=256, vocab=256, n_experts=4, moe_top_k=2,
        sliding_window=64, param_dtype="float32")
