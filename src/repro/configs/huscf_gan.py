"""The paper's own architecture: the 3M-param conditional GAN (Table 3),
exposed through the same registry for the launcher."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="huscf-gan", arch_type="gan", n_layers=5, d_model=256,
    n_heads=1, n_kv_heads=1, d_ff=0, vocab=10,
    citation="this paper (Table 3)",
    notes="cGAN generator+discriminator; trained via repro.core.huscf.")


def smoke() -> ArchConfig:
    return CONFIG
