"""LLaVA-NeXT 34B language backbone — anyres vision tiling feeds
precomputed patch embeddings (frontend stubbed per the carve-out)
[hf:llava-hf/llava-v1.6-mistral-7b-hf]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", arch_type="vlm", n_layers=60, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=20480, vocab=64000, head_dim=128,
    mlp_variant="swiglu", rope_theta=5e6, tie_embeddings=False,
    frontend="vision", num_prefix_embeds=2880,  # anyres: 5 tiles x 576
    long_context_variant="swa",
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    notes="Vision tower + projector stubbed: input_specs() supplies "
          "[B, 2880, d_model] patch embeddings (anyres 5-tile grid).")


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=256, vocab=256, num_prefix_embeds=16,
        param_dtype="float32")
