"""Command R+ 104B — dense GQA, no biases
[hf:CohereForAI/c4ai-command-r-v01]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b", arch_type="dense", n_layers=64,
    d_model=12288, n_heads=96, n_kv_heads=8, d_ff=33792, vocab=256000,
    head_dim=128, mlp_variant="swiglu", dense_bias=False,
    tie_embeddings=True, long_context_variant="swa",
    rope_theta=75e5,
    citation="hf:CohereForAI/c4ai-command-r-v01",
    notes="104B: params+Adam demand full FSDP+TP sharding; the dry-run "
          "proves fit on 256 chips (see EXPERIMENTS.md).")


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
        head_dim=32, d_ff=512, vocab=512, param_dtype="float32")
