"""Architecture config system.

Every assigned architecture is one `ArchConfig` in its own module (per
spec), registered under its public id for `--arch <id>` selection. Each
module also provides a `smoke()` reduced variant (<=2 layers, d_model
<=512, <=4 experts) for CPU tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                     # dense|moe|hybrid|ssm|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    citation: str = ""
    head_dim: Optional[int] = None
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    # attention details
    sliding_window: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mlp_variant: str = "swiglu"        # swiglu|geglu|gelu
    norm: str = "rmsnorm"              # rmsnorm|layernorm
    dense_bias: bool = False
    tie_embeddings: bool = True
    # layer pattern, cycled: entries in {attn, local_attn, rglru, mlstm, slstm}
    block_pattern: Tuple[str, ...] = ("attn",)
    local_window: int = 2048           # window for local_attn pattern entries
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    max_target_len: int = 0
    # modality frontend stub
    frontend: Optional[str] = None     # vision|audio
    num_prefix_embeds: int = 0         # patch/frame embeddings per example
    # recurrent dims
    d_rnn: Optional[int] = None
    # long-context applicability
    supports_long_context: bool = False   # natively sub-quadratic
    long_context_variant: Optional[str] = None  # e.g. 'swa' fallback
    # dtypes
    param_dtype: str = "bfloat16"
    # notes for DESIGN.md
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def dtype(self):
        return jnp.bfloat16 if self.param_dtype == "bfloat16" else jnp.float32

    def pattern_for_layer(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def param_count(self) -> int:
        """Analytic parameter count (sanity/rooline: 6ND model flops)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_attn = 0
        per_block = 0
        counts = {"attn": 0, "local_attn": 0, "rglru": 0, "mlstm": 0,
                  "slstm": 0}
        for i in range(self.n_layers):
            counts[self.pattern_for_layer(i)] += 1
        attn_params = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.n_experts:
            ff = self.n_experts * d * self.d_ff * (
                3 if self.mlp_variant in ("swiglu", "geglu") else 2) \
                + d * self.n_experts
        else:
            ff = d * self.d_ff * (
                3 if self.mlp_variant in ("swiglu", "geglu") else 2)
        d_rnn = self.d_rnn or d
        rglru_params = d * d_rnn * 2 + d_rnn * d_rnn * 2 + d_rnn * d + d_rnn
        mlstm_params = d * hd * self.n_heads * 4 + d * self.n_heads * 2 + \
            self.n_heads * hd * d
        slstm_params = d * d_rnn * 4 + d_rnn * d
        total = (counts["attn"] + counts["local_attn"]) * (attn_params + ff) \
            + counts["rglru"] * (rglru_params + ff) \
            + counts["mlstm"] * mlstm_params \
            + counts["slstm"] * slstm_params
        total += self.vocab * d  # embeddings (tied head)
        if self.is_encoder_decoder:
            total += self.n_enc_layers * (attn_params + ff) \
                + self.n_layers * attn_params  # cross attention
        total += self.n_layers * d * 2  # norms
        return int(total)

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top_k of n_experts), for the
        6*N_active*D MODEL_FLOPS roofline term."""
        total = self.param_count()
        if not self.n_experts:
            return total
        ff_one = self.d_model * self.d_ff * (
            3 if self.mlp_variant in ("swiglu", "geglu") else 2)
        n_moe = sum(1 for i in range(self.n_layers)
                    if self.pattern_for_layer(i) in ("attn", "local_attn"))
        inactive = n_moe * ff_one * (self.n_experts - self.moe_top_k)
        return int(total - inactive)


_REGISTRY: Dict[str, str] = {
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "llava-next-34b": "repro.configs.llava_next_34b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "gemma-7b": "repro.configs.gemma_7b",
    "qwen1.5-4b": "repro.configs.qwen1_5_4b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "huscf-gan": "repro.configs.huscf_gan",
}


def list_archs():
    return sorted(k for k in _REGISTRY if k != "huscf-gan")


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(_REGISTRY[name])
    return mod.CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    mod = importlib.import_module(_REGISTRY[name])
    return mod.smoke()


# ---------------------------------------------------------------------------
# input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
