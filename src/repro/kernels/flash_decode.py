"""Pallas TPU kernel: flash decode — one query token vs a long KV cache.

The serving hot spot for decode_32k / long_500k: out = softmax(q.K^T).V
with S up to 524288. HBM-bandwidth-bound (the whole cache streams once
per token), so the kernel's job is a single pass over S with an online
softmax, never materializing the [S] score vector in HBM.

TPU mapping: grid over (batch, S blocks); each step loads a
[BLOCK_S, KV*hd] cache tile into VMEM, computes q.k on the MXU, and
maintains running (max, denom, acc) f32 accumulators in VMEM scratch.
GQA handled by grouping H = KV * G query heads per kv head. The final
grid step normalizes. Masking via the logical cache length (ring
caches pass min(length, S)).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_S = 512
NEG_INF = -1e30


def _flash_decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, d_ref, *, block_s: int,
                         n_blocks: int):
    """Grid (B, n_blocks); one batch row x one cache block per step.

    q_ref [1, KV, G, hd]; k_ref/v_ref [1, block_s, KV, hd];
    o_ref [1, KV, G, hd]; scratch: acc [KV, G, hd], m/d [KV, G, 128].
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)

    q = q_ref[0].astype(jnp.float32)                     # [KV, G, hd]
    k = k_ref[0].astype(jnp.float32)                     # [S_blk, KV, hd]
    v = v_ref[0].astype(jnp.float32)
    hd = q.shape[-1]
    s = jnp.einsum("kgh,skh->kgs", q, k) / math.sqrt(hd)  # [KV, G, S_blk]
    pos = j * block_s + jnp.arange(block_s)
    valid = pos < len_ref[0]
    s = jnp.where(valid[None, None, :], s, NEG_INF)

    m_prev = m_ref[:, :, 0]                               # [KV, G]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])                     # [KV, G, S_blk]
    p = jnp.where(valid[None, None, :], p, 0.0)
    acc_ref[...] = acc_ref[...] * corr[..., None] + \
        jnp.einsum("kgs,skh->kgh", p, v)
    d_ref[:, :, 0] = d_ref[:, :, 0] * corr + jnp.sum(p, axis=-1)
    m_ref[:, :, 0] = m_new

    @pl.when(j == n_blocks - 1)
    def _finalize():
        denom = jnp.maximum(d_ref[:, :, 0], 1e-30)[..., None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 cache_len: jnp.ndarray, *, block_s: int = BLOCK_S,
                 interpret: bool = True) -> jnp.ndarray:
    """q [B, H, hd]; k/v [B, S, KV, hd]; cache_len scalar -> [B, H, hd]."""
    B, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    block_s = min(block_s, S)
    S_pad = -(-S // block_s) * block_s
    kp = jnp.pad(k, ((0, 0), (0, S_pad - S), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, S_pad - S), (0, 0), (0, 0)))
    qh = q.reshape(B, KV, G, hd)
    n_blocks = S_pad // block_s
    lens = jnp.broadcast_to(jnp.minimum(cache_len, S).astype(jnp.int32),
                            (B,))

    out = pl.pallas_call(
        functools.partial(_flash_decode_kernel, block_s=block_s,
                          n_blocks=n_blocks),
        grid=(B, n_blocks),
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (b,)),
            pl.BlockSpec((1, KV, G, hd), lambda b, j: (b, 0, 0, 0)),
            pl.BlockSpec((1, block_s, KV, hd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, block_s, KV, hd), lambda b, j: (b, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, KV, G, hd), lambda b, j: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((KV, G, hd), jnp.float32),
            pltpu.VMEM((KV, G, 128), jnp.float32),
            pltpu.VMEM((KV, G, 128), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qh, kp, vp)
    return out.reshape(B, H, hd)
