"""Jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the kernel
body runs as traced jnp on the host); on TPU set REPRO_PALLAS_COMPILE=1
to lower them for real. All wrappers are shape-polymorphic at the JAX
level and validated against repro.kernels.ref oracles in
tests/test_kernels.py.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import flash_decode as _fd
from repro.kernels import kmeans_assign as _km
from repro.kernels import mem_attention as _ma
from repro.kernels import weighted_agg as _wa

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


@functools.partial(jax.jit, static_argnames=())
def weighted_agg(stacked: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """out = sum_k w[k] * stacked[k, ...] (any trailing shape)."""
    K = stacked.shape[0]
    flat = stacked.reshape(K, -1)
    out = _wa.weighted_agg_flat(flat, weights, interpret=INTERPRET)
    return out.reshape(stacked.shape[1:])


@jax.jit
def clustered_agg(weights: jnp.ndarray, stacked: jnp.ndarray) -> jnp.ndarray:
    """Multi-output clustered aggregation: weights [S, K] rows are
    normalized (layer, cluster) segments; out[s] = sum_k W[s,k] *
    stacked[k, ...] in f32 (any trailing shape).

    NOTE: the clustered family takes weights FIRST (matmul order,
    ``W @ theta``), unlike the legacy ``weighted_agg(stacked, w)`` —
    a transposed call fails on shape unless S == K."""
    K = stacked.shape[0]
    flat = stacked.reshape(K, -1)
    out = _wa.clustered_agg_flat(weights, flat, interpret=INTERPRET)
    return out.reshape((weights.shape[0],) + stacked.shape[1:])


@jax.jit
def kmeans_assign(x: jnp.ndarray, centers: jnp.ndarray) -> jnp.ndarray:
    return _km.kmeans_assign(x, centers, interpret=INTERPRET)


@jax.jit
def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 cache_len: jnp.ndarray) -> jnp.ndarray:
    return _fd.flash_decode(q, k, v, cache_len, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("causal",))
def mem_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  lens: jnp.ndarray, causal: bool = True) -> jnp.ndarray:
    """Memory-efficient prefill attention: q [B, S, H, hd],
    k/v [B, S, KV, hd], lens [B] -> [B, S, H, hd] without ever
    materializing the [S, S] score tensor (the split-serving engine's
    server-segment prefill block)."""
    return _ma.mem_attention(q, k, v, lens, causal=causal,
                             interpret=INTERPRET)
