"""Pallas TPU kernel: memory-efficient (flash-style) prefill attention.

The split-serving engine's server-segment hot spot: a full [S, S]
attention over the prompt during the U-shaped LM prefill. A naive
softmax(q.K^T).V materializes the [H, S, S] score tensor; at serving
prompt lengths that is the peak-memory term. This kernel streams KV in
blocks with an online softmax — running (max, denom, acc) accumulators
in VMEM scratch, never more than one [block_q, block_k] score tile live
— the same recurrence as `flash_decode` extended from one query token
to a query block.

TPU mapping: grid (B, H, q blocks, k blocks), k innermost so the
scratch accumulators carry across a q block's KV sweep. Each step loads
a [block_q, hd] query tile and a [block_k, hd] KV tile into VMEM,
computes the tile's scores on the MXU, rescales the accumulator by
exp(m_prev - m_new) and folds the tile in; the last k block normalizes.
Causal masking (and the per-row valid-length mask for bucket-padded
cohorts) works off absolute positions, so out-of-diagonal tiles simply
contribute all-masked scores. GQA: kv head = query head // group size,
resolved in the BlockSpec index map.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _mem_attention_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                          acc_ref, m_ref, d_ref, *, block_q: int,
                          block_k: int, n_k: int, causal: bool):
    """One (batch, head, q block) x one k block per step.

    q_ref [1, block_q, 1, hd]; k_ref/v_ref [1, block_k, 1, hd];
    o_ref [1, block_q, 1, hd]; scratch: acc [block_q, hd],
    m/d [block_q, 128] (column 0 carries the running max / denom).
    """
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)

    q = q_ref[0, :, 0].astype(jnp.float32)               # [bq, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)               # [bk, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)
    hd = q.shape[-1]
    s = (q @ k.T) / math.sqrt(hd)                        # [bq, bk]

    qpos = qi * block_q + jnp.arange(block_q)
    kpos = kj * block_k + jnp.arange(block_k)
    mask = kpos[None, :] < len_ref[0]
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]                                 # [bq]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
    d_ref[:, 0] = d_ref[:, 0] * corr + jnp.sum(p, axis=-1)
    m_ref[:, 0] = m_new

    @pl.when(kj == n_k - 1)
    def _finalize():
        denom = jnp.maximum(d_ref[:, 0], 1e-30)[:, None]
        o_ref[0, :, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def mem_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  lens: jnp.ndarray, *, causal: bool = True,
                  block_q: int = BLOCK_Q, block_k: int = BLOCK_K,
                  interpret: bool = True) -> jnp.ndarray:
    """q [B, S, H, hd]; k/v [B, S, KV, hd] (H a multiple of KV — GQA);
    lens [B] or scalar valid prompt lengths -> [B, S, H, hd].

    Rows past ``lens`` (bucket padding in the serving cohort) see an
    all-masked score row and produce zeros-after-normalization garbage;
    callers slice or mask them — the engine pads per cut bucket and
    discards the tail.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    S_q = -(-S // block_q) * block_q
    S_k = -(-S // block_k) * block_k
    qp = jnp.pad(q, ((0, 0), (0, S_q - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, S_k - S), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, S_k - S), (0, 0), (0, 0)))
    n_q = S_q // block_q
    n_k = S_k // block_k
    lens_b = jnp.broadcast_to(jnp.minimum(lens, S).astype(jnp.int32), (B,))

    out = pl.pallas_call(
        functools.partial(_mem_attention_kernel, block_q=block_q,
                          block_k=block_k, n_k=n_k, causal=causal),
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, i, j: (b,)),
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S_q, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        interpret=interpret,
    )(lens_b, qp, kp, vp)
    return out[:, :S]
