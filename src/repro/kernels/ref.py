"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def weighted_agg_ref(stacked: jnp.ndarray, weights: jnp.ndarray
                     ) -> jnp.ndarray:
    """KLD-weighted federated aggregation: out = sum_k w[k] * x[k, ...].

    stacked [K, ...] any float dtype; weights [K] (already normalized).
    Accumulates in f32, returns stacked.dtype.
    """
    w = weights.astype(jnp.float32)
    flat = stacked.reshape(stacked.shape[0], -1).astype(jnp.float32)
    out = jnp.einsum("k,kd->d", w, flat)
    return out.reshape(stacked.shape[1:]).astype(stacked.dtype)


def clustered_agg_ref(weights: jnp.ndarray, stacked: jnp.ndarray
                      ) -> jnp.ndarray:
    """Multi-output clustered aggregation: out[s] = sum_k W[s,k] x[k].

    weights [S, K] (one normalized row per aggregation segment);
    stacked [K, ...] any float dtype. Accumulates and returns f32
    (the caller casts per-leaf on unflatten). Weights come first
    across the clustered family (matmul order ``W @ theta``), unlike
    the legacy single-output ``weighted_agg_ref(stacked, w)``.
    """
    w = weights.astype(jnp.float32)
    flat = stacked.reshape(stacked.shape[0], -1).astype(jnp.float32)
    out = w @ flat
    return out.reshape((w.shape[0],) + stacked.shape[1:])


def kmeans_assign_ref(x: jnp.ndarray, centers: jnp.ndarray) -> jnp.ndarray:
    """Nearest-center assignment: x [N, D], centers [M, D] -> labels [N]."""
    d2 = (jnp.sum(x.astype(jnp.float32) ** 2, -1)[:, None]
          - 2.0 * x.astype(jnp.float32) @ centers.astype(jnp.float32).T
          + jnp.sum(centers.astype(jnp.float32) ** 2, -1)[None, :])
    return jnp.argmin(d2, axis=1).astype(jnp.int32)


def mem_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      lens: jnp.ndarray, causal: bool = True
                      ) -> jnp.ndarray:
    """Full prefill GQA attention, dense scores (the thing the Pallas
    kernel avoids materializing).

    q [B, S, H, hd]; k/v [B, S, KV, hd]; lens [B] or scalar valid
    lengths. Returns [B, S, H, hd] (f32 accumulated, cast to q.dtype).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qh = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qh,
                   k.astype(jnp.float32)) / math.sqrt(hd)
    lens_b = jnp.broadcast_to(jnp.asarray(lens, jnp.int32), (B,))
    mask = jnp.arange(S)[None, :] < lens_b[:, None]        # [B, S]
    mask = mask[:, None, None, None, :]
    if causal:
        mask = mask & (jnp.arange(S)[None, :] <= jnp.arange(S)[:, None]
                       )[None, None, None, :, :]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)


def flash_decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     cache_len: jnp.ndarray) -> jnp.ndarray:
    """Single-token GQA decode attention.

    q [B, H, hd]; k/v [B, S, KV, hd]; cache_len scalar int32.
    Returns [B, H, hd] (f32 accumulated, cast to q.dtype).
    """
    B, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qh = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qh,
                   k.astype(jnp.float32)) / math.sqrt(hd)
    valid = jnp.arange(S)[None, :] < cache_len
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)
