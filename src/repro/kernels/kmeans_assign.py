"""Pallas TPU kernel: k-means nearest-center assignment.

labels[n] = argmin_m ||x[n] - c[m]||^2 — the inner step of the paper's
activation clustering (Eq. 12), dominated by the [N, D] x [D, M]
distance matmul.

TPU mapping: N is tiled into 128-row VMEM blocks (MXU-aligned); centers
[M, D] stay fully resident (M = #domains is tiny, D = activation dim up
to ~8k fits VMEM). The ||x||^2 term is constant under argmin and
dropped, so each block is one matmul on the MXU plus a VPU argmin:
    d2[n, m] ~ -2 x.c^T + ||c||^2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 128


def _kmeans_kernel(x_ref, c_ref, o_ref):
    """x_ref [ROWS, D]; c_ref [M, D]; o_ref [ROWS, 1] int32."""
    x = x_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    scores = -2.0 * jnp.dot(x, c.T, preferred_element_type=jnp.float32) \
        + jnp.sum(c * c, axis=-1)[None, :]
    o_ref[:, 0] = jnp.argmin(scores, axis=1).astype(jnp.int32)


def kmeans_assign(x: jnp.ndarray, centers: jnp.ndarray, *,
                  interpret: bool = True) -> jnp.ndarray:
    """x [N, D], centers [M, D] -> labels [N] int32."""
    N, D = x.shape
    M = centers.shape[0]
    N_pad = -(-N // ROW_TILE) * ROW_TILE
    xp = jnp.pad(x, ((0, N_pad - N), (0, 0)))
    out = pl.pallas_call(
        _kmeans_kernel,
        grid=(N_pad // ROW_TILE,),
        in_specs=[
            pl.BlockSpec((ROW_TILE, D), lambda i: (i, 0)),
            pl.BlockSpec((M, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N_pad, 1), jnp.int32),
        interpret=interpret,
    )(xp, centers)
    return out[:N, 0]
