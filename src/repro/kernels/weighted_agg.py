"""Pallas TPU kernels: KLD-weighted federated parameter aggregation.

Two entry points share one kernel body:

``weighted_agg_flat``   — the original single-output reduction
    out[d] = sum_k w[k] * theta[k, d] over a flat parameter vector.

``clustered_agg_flat``  — the multi-output clustered generalization
    agg[s, d] = sum_k W[s, k] * theta[k, d]
    i.e. ``W @ theta`` with the (small) weight matrix resident in VMEM
    and the parameter axis streamed in (SUBLANE, LANE) = (8, 1024)
    tiles.  One row of ``W`` per (layer, cluster) aggregation segment:
    this computes *every* cluster aggregate of a federation round (Eq.
    16) in a single ``pallas_call`` per network instead of one dispatch
    per (layer, cluster, leaf).  The block-diagonal "one row per
    receiving client copy" broadcast matrix factors exactly as
    ``W_full = B @ W`` with ``B`` one-hot; the cheap ``B`` gather is
    applied outside the kernel (see repro.core.federation), so the
    kernel only streams theta once and writes S aggregate rows rather
    than M >> S broadcast rows.

TPU mapping: the flat parameter axis is tiled into (8, 1024)-shaped
VMEM blocks (sublane x lane aligned); the weight matrix stays resident
per block so each block is one [S, K] x [K, 8*1024] contraction on the
MXU/VPU — arithmetic intensity is low (streaming reduction), so the
kernel is HBM-bandwidth-bound and the tiling keeps aligned 2D tiles
streaming through VMEM exactly once.

Sharded rounds (repro.core.federation, ``mesh=``) invoke the same
kernel *per shard* inside a ``shard_map`` over the client axis: each
device's block sees only its local ``[K/n, D]`` row slice of theta
(and the matching ``[S, K/n]`` column slice of ``W``), computes the
local partial aggregate, and the cross-device ``psum`` happens outside
the kernel — the kernel body is oblivious to the mesh, K is simply
smaller.  (shard_map needs ``check_rep=False`` around pallas_call;
the caller handles that.)

``block_tiles`` groups several (8, 1024) tiles into one grid step.  On
real TPU keep the default of 1 (a [K, 8, 1024] block per step fits
VMEM); in interpret mode (the CPU oracle path) the emulator pays a
full-operand copy per grid step, so the wrapper coalesces the whole
parameter axis into a single step — same kernel body, same math.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024        # lane-dim tile (multiple of 128)
SUBLANE = 8        # sublane tile


def _clustered_agg_kernel(w_ref, x_ref, o_ref):
    """Blocks: w_ref [S, K]; x_ref [K, T, SUBLANE, LANE]; o_ref
    [S, T, SUBLANE, LANE]. One [S, K] x [K, T*SUBLANE*LANE] matmul
    per grid step (T = block_tiles)."""
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    K = x.shape[0]
    agg = jax.lax.dot_general(w, x.reshape(K, -1),
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[...] = agg.reshape((w.shape[0],) + x.shape[1:])


def clustered_agg_flat(weights: jnp.ndarray, stacked_flat: jnp.ndarray, *,
                       block_tiles: int | None = None,
                       interpret: bool = True) -> jnp.ndarray:
    """Multi-output clustered aggregation: weights [S, K] @
    stacked_flat [K, D] -> [S, D] f32; D padded to SUBLANE*LANE tiles.

    Each weight row is one aggregation segment (a (layer, cluster)
    block of the federation round), already normalized over its
    members and zero elsewhere.
    """
    K, D = stacked_flat.shape
    S = weights.shape[0]
    tile = SUBLANE * LANE
    n_tiles = max(1, -(-D // tile))
    if block_tiles is None:
        # interpret mode pays a full-operand copy per grid step — run
        # the whole parameter axis in one step; compiled TPU streams
        # tile by tile.
        block_tiles = n_tiles if interpret else 1
    steps = -(-n_tiles // block_tiles)
    D_pad = steps * block_tiles * tile
    x = jnp.pad(stacked_flat, ((0, 0), (0, D_pad - D)))
    x = x.reshape(K, steps * block_tiles, SUBLANE, LANE)
    w = weights.astype(jnp.float32)

    out = pl.pallas_call(
        _clustered_agg_kernel,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((S, K), lambda i: (0, 0)),
            pl.BlockSpec((K, block_tiles, SUBLANE, LANE),
                         lambda i: (0, i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((S, block_tiles, SUBLANE, LANE),
                               lambda i: (0, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S, steps * block_tiles, SUBLANE,
                                        LANE), jnp.float32),
        interpret=interpret,
    )(w, x)
    return out.reshape(S, D_pad)[:, :D]


def weighted_agg_flat(stacked_flat: jnp.ndarray, weights: jnp.ndarray, *,
                      interpret: bool = True) -> jnp.ndarray:
    """stacked_flat [K, D] -> [D]: the degenerate single-segment case
    of ``clustered_agg_flat`` (S=1, all clients in one cluster)."""
    out = clustered_agg_flat(weights.reshape(1, -1), stacked_flat,
                             block_tiles=None if interpret else 1,
                             interpret=interpret)
    return out[0].astype(stacked_flat.dtype)
