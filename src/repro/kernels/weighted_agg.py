"""Pallas TPU kernel: KLD-weighted federated parameter aggregation.

out[d] = sum_k w[k] * theta[k, d] over a flat parameter vector — the
server-side hot spot of every federation round (Eq. 16): ~3M params x
K clients per GAN round, or gigabytes for the split-transformer mode.

TPU mapping: the flat parameter axis is tiled into (8, 1024)-shaped VMEM
blocks (sublane x lane aligned); the client axis K stays resident per
block so each block is one [K] x [K, 8*1024] contraction on the VPU —
arithmetic intensity is low (streaming reduction), so the kernel is HBM
-bandwidth-bound and the tiling simply keeps the MXU/VPU fed with
aligned 2D tiles while streaming theta once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024        # lane-dim tile (multiple of 128)
SUBLANE = 8        # sublane tile


def _weighted_agg_kernel(w_ref, x_ref, o_ref):
    """Blocks: w_ref [K, 1]; x_ref [K, 1, SUBLANE, LANE]; o_ref
    [1, SUBLANE, LANE]. One weighted reduction over K per tile."""
    x = x_ref[...].astype(jnp.float32)[:, 0]    # [K, 8, LANE]
    w = w_ref[...].astype(jnp.float32)[:, 0]    # [K]
    o_ref[0, :, :] = jnp.einsum("ksl,k->sl", x, w)


def weighted_agg_flat(stacked_flat: jnp.ndarray, weights: jnp.ndarray, *,
                      interpret: bool = True) -> jnp.ndarray:
    """stacked_flat [K, D] -> [D]; D padded to SUBLANE*LANE tiles."""
    K, D = stacked_flat.shape
    tile = SUBLANE * LANE
    D_pad = -(-D // tile) * tile
    x = jnp.pad(stacked_flat, ((0, 0), (0, D_pad - D)))
    x = x.reshape(K, D_pad // tile, SUBLANE, LANE)
    w = weights.reshape(K, 1)
    n_blocks = D_pad // tile

    out = pl.pallas_call(
        _weighted_agg_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, 1, SUBLANE, LANE), lambda i: (0, i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, SUBLANE, LANE), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, SUBLANE, LANE),
                                       jnp.float32),
        interpret=interpret,
    )(w, x)
    return out.reshape(D_pad)[:D].astype(stacked_flat.dtype)
