"""Synthetic token streams for LM training/serving examples.

Zipf-distributed unigrams mixed with short copy/repeat motifs so a small
LM has learnable structure. Deterministic per (seed, vocab).
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def zipf_tokens(rng: np.random.Generator, n: int, vocab: int,
                alpha: float = 1.1) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    p /= p.sum()
    return rng.choice(vocab, size=n, p=p).astype(np.int32)


def lm_batches(vocab: int, batch: int, seq_len: int, *, seed: int = 0,
               motif_len: int = 16) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yields (tokens [B,S], labels [B,S]) forever; labels are next-token."""
    rng = np.random.default_rng(seed)
    while True:
        toks = zipf_tokens(rng, batch * (seq_len + 1), vocab).reshape(
            batch, seq_len + 1)
        # inject copy motifs: second half repeats a window from first half
        for i in range(batch):
            if rng.random() < 0.5 and seq_len > 2 * motif_len:
                start = rng.integers(0, seq_len // 2 - motif_len)
                dst = rng.integers(seq_len // 2, seq_len - motif_len)
                toks[i, dst: dst + motif_len] = toks[i, start: start + motif_len]
        yield toks[:, :-1], toks[:, 1:]
