"""Device-resident data pipeline for the split-learning trainer.

`DeviceDataset` stages every profile group's client datasets on device
once — padded per-client rows plus valid counts — so training epochs
never touch host numpy again: batches are drawn *inside* the jitted
step (`sample_batch`) by `jax.random` gathers over on-device indices,
and `jax.lax.scan` can fuse whole epochs into one dispatch
(`repro.core.huscf`, DESIGN.md §Device-resident epochs).

Layout per group (clients in the group's canonical order):
  * images [K_p, n_max, H, W, C] f32 — rows zero-padded past each
    client's ``n``
  * labels [K_p, n_max] int32 — padding holds ``-1`` as a sentinel so
    an out-of-bounds gather is detectable (tests assert labels >= 0)
  * counts [K_p] int32 — the valid row count per client; samplers draw
    indices in [0, counts[k]) so padding is never read

With a mesh, rows stage sharded over the mesh's client axes
(`sharding.policy.client_stack_sharding`): the same ('pod', 'data')
placement as every population-batch tensor, with the usual
divisibility fallback to replication.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.partition import ClientSpec, padded_stack
from repro.sharding.policy import client_stack_sharding

if TYPE_CHECKING:  # runtime import would cycle: repro.core imports
    from repro.core.splitting import ProfileGroup  # repro.data (huscf)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceDataset:
    """Per-group padded client rows, staged on device once.

    A pytree (group order is static aux data), so it can be passed as
    an argument to jitted step/epoch functions — keeping any mesh
    shardings intact, which a closed-over constant would not.
    """
    order: Tuple[str, ...]
    images: Dict[str, Any]     # gname -> [K_p, n_max, H, W, C] f32
    labels: Dict[str, Any]     # gname -> [K_p, n_max] int32 (-1 pad)
    counts: Dict[str, Any]     # gname -> [K_p] int32

    def tree_flatten(self):
        return (self.images, self.labels, self.counts), self.order

    @classmethod
    def tree_unflatten(cls, order, children):
        return cls(order, *children)

    @property
    def n_clients(self) -> int:
        return sum(int(c.shape[0]) for c in self.counts.values())


def stage_clients(groups: Sequence["ProfileGroup"],
                  clients: Sequence[ClientSpec],
                  mesh: Optional[Any] = None) -> DeviceDataset:
    """Pad + upload every group's client datasets. ``mesh`` shards the
    leading client axis (replicates everything on the mesh's devices
    when a group's size is not divisible) so the training step and the
    federation round live on one device set."""
    images, labels, counts = {}, {}, {}
    order = tuple(g.name for g in groups)
    for g in groups:
        imgs, labs, cnt = padded_stack([clients[cid] for cid in g.client_ids])
        if (cnt <= 0).any():
            # fail as loudly as the host sampler's rng.integers(0, 0)
            # did: randint(0, 0) yields index 0 and the gather would
            # silently read the -1 sentinel padding
            empty = [int(c) for c, n in zip(g.client_ids, cnt) if n <= 0]
            raise ValueError(f"clients {empty} in group {g.name} have no "
                             "samples — cannot stage an empty dataset")
        if mesh is not None and mesh.devices.size > 1:
            put = lambda x: jax.device_put(
                x, client_stack_sharding(mesh, x.shape))
        else:
            put = jnp.asarray
        images[g.name] = put(imgs)
        labels[g.name] = put(labs)
        counts[g.name] = put(cnt)
    return DeviceDataset(order, images, labels, counts)


def sample_batch(ds: DeviceDataset, key, *, batch: int, z_dim: int,
                 num_classes: int) -> Dict[str, Dict[str, jnp.ndarray]]:
    """Draw one training batch entirely on device (jit-safe).

    Real rows are gathered by per-client indices drawn in
    [0, counts[k]) — padding rows are unreachable by construction —
    and z / fake_y come from the same threaded PRNG key. Group
    subkeys fold in the staged group order, so the stream is a pure
    function of (key, topology)."""
    out: Dict[str, Dict[str, jnp.ndarray]] = {
        "real_img": {}, "real_y": {}, "z": {}, "fake_y": {}}
    gather = jax.vmap(lambda rows, ix: jnp.take(rows, ix, axis=0))
    for i, name in enumerate(ds.order):
        k_idx, k_z, k_y = jax.random.split(jax.random.fold_in(key, i), 3)
        counts = ds.counts[name]
        k_cl = counts.shape[0]
        idx = jax.random.randint(k_idx, (k_cl, batch), 0, counts[:, None])
        out["real_img"][name] = gather(ds.images[name], idx)
        out["real_y"][name] = gather(ds.labels[name], idx)
        out["z"][name] = jax.random.normal(k_z, (k_cl, batch, z_dim),
                                           jnp.float32)
        out["fake_y"][name] = jax.random.randint(k_y, (k_cl, batch), 0,
                                                 num_classes, jnp.int32)
    return out
