"""Procedural multi-domain image datasets.

The paper evaluates on MNIST / FMNIST / KMNIST / NotMNIST / MedMNIST /
CIFAR10 / SVHN — none of which are available offline.  We generate
*structured* class-conditional image families whose statistics mimic the
relevant properties:

 * each **domain** is a distinct procedural family (oriented gratings,
   gaussian blob constellations, checkerboards, concentric rings) so the
   discriminator's mid-layer activations genuinely separate domains —
   which is exactly what HuSCF-GAN's clustering stage must detect;
 * each **class** (10 per domain) parameterizes the family (orientation,
   blob layout, frequency, radius) so class-conditional generation and
   classifier-based evaluation are meaningful;
 * pixel noise + per-sample jitter make the task non-trivial.

Images are [H, W, 1] float32 in [-1, 1] (cGAN tanh range), default 28x28.
"""
from __future__ import annotations

import functools
import zlib
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

DOMAINS = ("gratings", "blobs", "checkers", "rings")
NUM_CLASSES = 10


def _grid(img_size: int):
    ax = np.linspace(-1.0, 1.0, img_size, dtype=np.float32)
    return np.meshgrid(ax, ax, indexing="ij")


def _gratings(cls: np.ndarray, img_size: int, rng: np.random.Generator):
    """Oriented sinusoidal gratings; class -> orientation."""
    yy, xx = _grid(img_size)
    n = cls.shape[0]
    theta = cls * (np.pi / NUM_CLASSES) + rng.normal(0, 0.05, n)
    freq = 4.0 + (cls % 3) + rng.normal(0, 0.1, n)
    phase = rng.uniform(0, 2 * np.pi, n)
    t = theta[:, None, None]
    proj = np.cos(t) * xx[None] + np.sin(t) * yy[None]
    return np.sin(freq[:, None, None] * np.pi * proj + phase[:, None, None])


def _blobs(cls: np.ndarray, img_size: int, rng: np.random.Generator):
    """Constellations of gaussian blobs; class -> #blobs and ring radius."""
    yy, xx = _grid(img_size)
    n = cls.shape[0]
    img = np.full((n, img_size, img_size), -1.0, np.float32)
    for i in range(n):
        k = int(cls[i]) % 5 + 1
        r = 0.25 + 0.5 * ((int(cls[i]) // 5) + 1) / 3.0
        ang0 = rng.uniform(0, 2 * np.pi)
        for j in range(k):
            a = ang0 + 2 * np.pi * j / k
            cx, cy = r * np.cos(a), r * np.sin(a)
            cx += rng.normal(0, 0.03)
            cy += rng.normal(0, 0.03)
            d2 = (xx - cx) ** 2 + (yy - cy) ** 2
            img[i] += 2.0 * np.exp(-d2 / 0.02)
    return np.clip(img, -1.0, 1.0)


def _checkers(cls: np.ndarray, img_size: int, rng: np.random.Generator):
    """Checkerboards; class -> tile count, parity."""
    yy, xx = _grid(img_size)
    n = cls.shape[0]
    tiles = 2.0 + (cls % 5)
    parity = (cls // 5).astype(np.float32)
    ox = rng.uniform(-0.1, 0.1, n)[:, None, None]
    oy = rng.uniform(-0.1, 0.1, n)[:, None, None]
    t = tiles[:, None, None]
    a = np.floor((xx[None] + 1 + ox) * t / 2) + np.floor((yy[None] + 1 + oy) * t / 2)
    board = (np.mod(a, 2.0) * 2.0 - 1.0)
    return board * (1.0 - 2.0 * parity[:, None, None])


def _rings(cls: np.ndarray, img_size: int, rng: np.random.Generator):
    """Concentric rings; class -> radial frequency & center offset."""
    yy, xx = _grid(img_size)
    n = cls.shape[0]
    freq = 2.0 + (cls % 5) * 1.5
    off = 0.3 * (cls // 5).astype(np.float32)
    jx = rng.normal(0, 0.02, n)[:, None, None]
    jy = rng.normal(0, 0.02, n)[:, None, None]
    rr = np.sqrt((xx[None] - off[:, None, None] - jx) ** 2 + (yy[None] - jy) ** 2)
    return np.cos(freq[:, None, None] * np.pi * rr)


_FAMILIES = {"gratings": _gratings, "blobs": _blobs,
             "checkers": _checkers, "rings": _rings}


def _domain_salt(domain: str) -> int:
    # NOT hash(): str hashing is randomized per process (PYTHONHASHSEED),
    # which made seed= silently non-reproducible across runs.
    return zlib.crc32(domain.encode()) % (2 ** 16)


def make_dataset(domain: str, n: int, *, img_size: int = 28, seed: int = 0,
                 noise: float = 0.12) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (images [n, H, W, 1] in [-1,1], labels [n] int32)."""
    assert domain in _FAMILIES, f"unknown domain {domain}"
    rng = np.random.default_rng(seed + _domain_salt(domain))
    labels = rng.integers(0, NUM_CLASSES, n).astype(np.int32)
    imgs = _FAMILIES[domain](labels, img_size, rng).astype(np.float32)
    imgs = imgs + rng.normal(0, noise, imgs.shape).astype(np.float32)
    imgs = np.clip(imgs, -1.0, 1.0)[..., None]
    return imgs, labels


def make_class_balanced(domain: str, per_class: int, *, img_size: int = 28,
                        seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed + 7 + _domain_salt(domain))
    labels = np.repeat(np.arange(NUM_CLASSES, dtype=np.int32), per_class)
    imgs = _FAMILIES[domain](labels, img_size, rng).astype(np.float32)
    imgs = imgs + rng.normal(0, 0.12, imgs.shape).astype(np.float32)
    return np.clip(imgs, -1.0, 1.0)[..., None], labels
