from repro.data.synthetic import DOMAINS, NUM_CLASSES, make_dataset, make_class_balanced
from repro.data.partition import (ClientSpec, build_scenario, padded_stack,
                                  partition_domain, batches)
from repro.data.pipeline import DeviceDataset, sample_batch, stage_clients
from repro.data.tokens import lm_batches

__all__ = ["DOMAINS", "NUM_CLASSES", "make_dataset", "make_class_balanced",
           "ClientSpec", "build_scenario", "partition_domain", "batches",
           "padded_stack", "DeviceDataset", "sample_batch", "stage_clients",
           "lm_batches"]
