"""Non-IID client partitioner reproducing the paper's scenarios.

The paper's heterogeneity recipe (§6.1.x):
  * label exclusion — "40 clients have 2 labels excluded, 10 have 3, ..."
  * dataset-size variation — clients hold 600 / 400 / 200 / 100 samples
  * multi-domain — disjoint client groups draw from different domains

`ClientSpec` captures one client's data; `build_scenario` constructs the
paper's eight scenarios (parameterized so tests can shrink them).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.synthetic import DOMAINS, NUM_CLASSES, make_dataset


@dataclasses.dataclass
class ClientSpec:
    client_id: int
    domain: str
    images: np.ndarray  # [n, H, W, 1]
    labels: np.ndarray  # [n]

    @property
    def n(self) -> int:
        return int(self.labels.shape[0])

    def label_distribution(self) -> np.ndarray:
        hist = np.bincount(self.labels, minlength=NUM_CLASSES).astype(np.float64)
        return hist / max(hist.sum(), 1.0)


def _exclude_labels(images, labels, excluded: Sequence[int]):
    mask = ~np.isin(labels, np.asarray(list(excluded), dtype=labels.dtype))
    return images[mask], labels[mask]


def partition_domain(domain: str, client_ids: Sequence[int], *,
                     sizes: Sequence[int], exclusions: Sequence[Sequence[int]],
                     img_size: int = 28, seed: int = 0) -> List[ClientSpec]:
    """Build one domain's client population.

    sizes[i] / exclusions[i] describe client i (pre-exclusion target size).
    """
    assert len(client_ids) == len(sizes) == len(exclusions)
    out = []
    for i, cid in enumerate(client_ids):
        # oversample so exclusion still leaves ~sizes[i] items
        raw_n = int(sizes[i] * (1.0 + 0.25 * len(exclusions[i]) + 0.2)) + 8
        imgs, labs = make_dataset(domain, raw_n, img_size=img_size,
                                  seed=seed * 10007 + cid)
        if exclusions[i]:
            imgs, labs = _exclude_labels(imgs, labs, exclusions[i])
        imgs, labs = imgs[: sizes[i]], labs[: sizes[i]]
        out.append(ClientSpec(cid, domain, imgs, labs))
    return out


def paper_exclusion_plan(num_clients: int, plan: Sequence[Tuple[int, int]],
                         seed: int = 0) -> List[List[int]]:
    """plan: [(num_clients_affected, num_labels_excluded), ...].

    Remaining clients keep all labels. Mirrors e.g. 'within each domain,
    20 clients have two labels excluded, 5 have three, 5 have four'.
    """
    rng = np.random.default_rng(seed)
    exclusions: List[List[int]] = [[] for _ in range(num_clients)]
    order = rng.permutation(num_clients)
    idx = 0
    for count, n_excl in plan:
        for _ in range(count):
            if idx >= num_clients:
                break
            cid = order[idx]
            exclusions[cid] = list(rng.choice(NUM_CLASSES, n_excl, replace=False))
            idx += 1
    return exclusions


def build_scenario(name: str, *, num_clients: int = 100, base_size: int = 600,
                   img_size: int = 28, seed: int = 0) -> List[ClientSpec]:
    """The paper's test scenarios (Table 5), shrinkable for tests.

    Supported names:
      1dom_iid | 1dom_noniid | 2dom_iid | 2dom_noniid | 2dom_highly_noniid
      | 4dom_iid | 2dom_medical | 2dom_highres  (last two map to distinct
      synthetic domain pairs since the real datasets are offline-absent)
    """
    rng = np.random.default_rng(seed + 99)
    half = num_clients // 2
    quarter = num_clients // 4

    def scale(x):  # scale the paper's per-100-client counts
        return max(1, int(round(x * num_clients / 100)))

    if name == "1dom_iid":
        sizes = [base_size] * num_clients
        excl = [[] for _ in range(num_clients)]
        return partition_domain("gratings", range(num_clients), sizes=sizes,
                                exclusions=excl, img_size=img_size, seed=seed)

    if name == "1dom_noniid":
        plan = [(scale(40), 2), (scale(10), 3), (scale(10), 4)]
        excl = paper_exclusion_plan(num_clients, plan, seed)
        sizes = [base_size if rng.random() < 0.5 else int(base_size * 2 / 3)
                 for _ in range(num_clients)]
        return partition_domain("gratings", range(num_clients), sizes=sizes,
                                exclusions=excl, img_size=img_size, seed=seed)

    def two_dom(d0, d1, noniid: bool, highly: bool = False):
        specs: List[ClientSpec] = []
        for g, dom in ((0, d0), (1, d1)):
            ids = list(range(g * half, g * half + half))
            if highly:
                plan = [(scale(20) // 1, 2), (scale(30), 3)]
                size_pool = [base_size, base_size // 3, base_size // 6]
            elif noniid:
                plan = [(scale(20), 2), (scale(5), 3), (scale(5), 4)]
                size_pool = [base_size, int(base_size * 2 / 3)]
            else:
                plan, size_pool = [], [base_size]
            excl = paper_exclusion_plan(half, plan, seed + g)
            sizes = [int(rng.choice(size_pool)) for _ in range(half)]
            specs += partition_domain(dom, ids, sizes=sizes, exclusions=excl,
                                      img_size=img_size, seed=seed + g)
        return specs

    if name == "2dom_iid":
        return two_dom("gratings", "blobs", noniid=False)
    if name == "2dom_noniid":
        return two_dom("gratings", "blobs", noniid=True)
    if name == "2dom_highly_noniid":
        return two_dom("gratings", "blobs", noniid=True, highly=True)
    if name == "2dom_medical":
        return two_dom("rings", "checkers", noniid=True)
    if name == "2dom_highres":
        return two_dom("checkers", "blobs", noniid=True, highly=True)

    if name == "4dom_iid":
        specs = []
        for g, dom in enumerate(DOMAINS):
            ids = list(range(g * quarter, (g + 1) * quarter))
            sizes = [base_size] * quarter
            excl = [[] for _ in range(quarter)]
            specs += partition_domain(dom, ids, sizes=sizes, exclusions=excl,
                                      img_size=img_size, seed=seed + g)
        return specs

    raise ValueError(f"unknown scenario {name}")


def padded_stack(specs: Sequence[ClientSpec]
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stack clients' datasets into padded per-client rows.

    Returns (images [K, n_max, H, W, C] f32 zero-padded,
    labels [K, n_max] int32 with ``-1`` sentinel padding,
    counts [K] int32). The sentinel makes an out-of-range gather
    observable — samplers must only draw indices below ``counts``
    (see repro.data.pipeline).
    """
    k = len(specs)
    n_max = max(s.n for s in specs)
    images = np.zeros((k, n_max) + specs[0].images.shape[1:], np.float32)
    labels = np.full((k, n_max), -1, np.int32)
    counts = np.zeros(k, np.int32)
    for i, s in enumerate(specs):
        images[i, : s.n] = s.images
        labels[i, : s.n] = s.labels
        counts[i] = s.n
    return images, labels, counts


def batches(spec: ClientSpec, batch_size: int, rng: np.random.Generator):
    """Yield an epoch of shuffled batches (pads by wraparound)."""
    n = spec.n
    idx = rng.permutation(n)
    n_batches = max(1, n // batch_size)
    for b in range(n_batches):
        sel = idx[b * batch_size:(b + 1) * batch_size]
        if sel.shape[0] < batch_size:
            sel = np.concatenate([sel, idx[: batch_size - sel.shape[0]]])
        yield spec.images[sel], spec.labels[sel]
