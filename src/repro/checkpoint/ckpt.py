"""Pytree checkpointing via msgpack + raw numpy buffers.

Layout-stable: a checkpoint is {treedef_repr, leaves: [{dtype, shape,
data}]} in one msgpack file. Restores onto a template pytree so custom
nodes (lists/dicts/NamedTuples) round-trip.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack_leaf(x) -> dict:
    arr = np.asarray(x)
    # store the canonical name ('bfloat16', 'float32', ...) — ml_dtypes
    # registers the extended float types with numpy so np.dtype(name)
    # round-trips
    return {"dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes()}


def _unpack_leaf(d: dict) -> np.ndarray:
    import ml_dtypes  # noqa: F401  (registers bfloat16/f8 with numpy)
    return np.frombuffer(d["data"], dtype=np.dtype(d["dtype"])
                         ).reshape(d["shape"])


def save_checkpoint(path: str, tree: Any, *, step: int = 0) -> None:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = {"step": step, "treedef": str(treedef),
               "leaves": [_pack_leaf(x) for x in leaves]}
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def load_checkpoint(path: str, template: Any) -> tuple:
    """Returns (tree_like_template, step). Validates structure + shapes."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    t_leaves, treedef = jax.tree_util.tree_flatten(template)
    if str(treedef) != payload["treedef"]:
        raise ValueError("checkpoint treedef mismatch")
    loaded = [_unpack_leaf(d) for d in payload["leaves"]]
    if len(loaded) != len(t_leaves):
        raise ValueError("checkpoint leaf count mismatch")
    out = []
    for got, want in zip(loaded, t_leaves):
        if tuple(got.shape) != tuple(np.shape(want)):
            raise ValueError(f"shape mismatch {got.shape} vs {np.shape(want)}")
        out.append(jnp.asarray(got, dtype=want.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), payload["step"]
