"""Analytic latency model — paper §4.2, Eq. (3)–(10).

One training iteration of the split cGAN across K heterogeneous clients
and one server. Per-client four cut points; per-layer server barriers.

Indexing convention (half-open segments over n layers):
    head  = layers [0, l_H)      l_H >= 1
    server= layers [l_H, l_T)    must contain the middle layer
    tail  = layers [l_T, n)      l_T <= n - 1

Eq. (3)/(4): compute latency = b * FLOPs / (f * kappa).
Eq. (5)/(6): transmission latency = b * activation_bytes_at_cut / rate.
Eq. (7)/(8): cumulative per-layer server schedule with client-join
             barriers (the server serializes per-layer work across the
             N_i clients active at layer i, and cannot start layer i
             before the slowest client whose head ends at i delivers).
Eq. (9)/(10): total L_T = L_G^F + L_G^B + 3 (L_D^F + L_D^B).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from repro.models.gan import GEN_LAYER_COSTS, DISC_LAYER_COSTS, LayerCost


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Paper Table 4 row."""
    name: str
    freq_hz: float
    flops_per_cycle: float
    rate_bytes_per_s: float

    @property
    def flops_per_s(self) -> float:
        return self.freq_hz * self.flops_per_cycle


# Paper Table 4 (frequencies in MHz there).
PAPER_DEVICES: Tuple[DeviceProfile, ...] = (
    DeviceProfile("device1", 480e6, 1, 50e6),
    DeviceProfile("device2", 6000e6, 8, 150e6),
    DeviceProfile("device3", 15600e6, 8, 1000e6),
    DeviceProfile("device4", 5720e6, 8, 300e6),
    DeviceProfile("device5", 4000e6, 4, 50e6),
    DeviceProfile("device6", 9000e6, 4, 100e6),
    DeviceProfile("device7", 12000e6, 10, 800e6),
)
PAPER_SERVER = DeviceProfile("server", 42000e6, 16, 1000e6)


@dataclasses.dataclass(frozen=True)
class Cut:
    """Four cut points for one client: (G head end, G tail start, D head end, D tail start)."""
    g_h: int
    g_t: int
    d_h: int
    d_t: int

    def as_tuple(self) -> Tuple[int, int, int, int]:
        return (self.g_h, self.g_t, self.d_h, self.d_t)


def valid_cuts(n_layers: int) -> List[Tuple[int, int]]:
    """All (l_H, l_T) with >=1 head layer, >=1 tail layer, middle on server."""
    mid = n_layers // 2
    return [(h, t) for h in range(1, mid + 1)
            for t in range(mid + 1, n_layers)]


def all_cut_options(n_g: int = 5, n_d: int = 5) -> List[Cut]:
    return [Cut(gh, gt, dh, dt)
            for gh, gt in valid_cuts(n_g)
            for dh, dt in valid_cuts(n_d)]


def _segment_flops(costs: Sequence[LayerCost], start: int, stop: int,
                   backward: bool) -> float:
    if backward:
        return sum(c.flops_bwd for c in costs[start:stop])
    return sum(c.flops_fwd for c in costs[start:stop])


def _one_net_latency(costs: Sequence[LayerCost],
                     cuts: Sequence[Tuple[int, int]],
                     devices: Sequence[DeviceProfile],
                     server: DeviceProfile, batch: int,
                     ) -> Tuple[float, float]:
    """Forward & backward latency (Eq. 7-9) for one network (G or D)."""
    n = len(costs)
    b = float(batch)
    K = len(cuts)

    head_f = [b * _segment_flops(costs, 0, cuts[k][0], False) / devices[k].flops_per_s
              for k in range(K)]
    head_b = [b * _segment_flops(costs, 0, cuts[k][0], True) / devices[k].flops_per_s
              for k in range(K)]
    tail_f = [b * _segment_flops(costs, cuts[k][1], n, False) / devices[k].flops_per_s
              for k in range(K)]
    tail_b = [b * _segment_flops(costs, cuts[k][1], n, True) / devices[k].flops_per_s
              for k in range(K)]
    # uplink: bytes of head's final activation (fwd) / tail-input gradient (bwd)
    up_f = [b * costs[cuts[k][0] - 1].act_bytes / devices[k].rate_bytes_per_s
            for k in range(K)]
    up_b = [b * costs[cuts[k][1] - 1].act_bytes / devices[k].rate_bytes_per_s
            for k in range(K)]
    # downlink from server
    down_f = [b * costs[cuts[k][1] - 1].act_bytes / server.rate_bytes_per_s
              for k in range(K)]
    down_b = [b * costs[cuts[k][0] - 1].act_bytes / server.rate_bytes_per_s
              for k in range(K)]

    # server per-layer compute (per participating client)
    srv_f = [b * costs[i].flops_fwd / server.flops_per_s for i in range(n)]
    srv_b = [b * costs[i].flops_bwd / server.flops_per_s for i in range(n)]
    n_active = [sum(1 for k in range(K) if cuts[k][0] <= i < cuts[k][1])
                for i in range(n)]

    # Eq. 7 forward cumulative schedule over server layers
    S_f = [0.0] * (n + 1)  # S_f[i+1] = latency through server layer i
    for i in range(n):
        joins = [head_f[k] + up_f[k] for k in range(K) if cuts[k][0] == i]
        barrier = max(joins) if joins else 0.0
        S_f[i + 1] = max(S_f[i] + srv_f[i] * n_active[i], barrier)

    # Eq. 9 forward total: slowest client finishing its tail
    L_f = max(S_f[cuts[k][1]] + down_f[k] + tail_f[k] for k in range(K))

    # Eq. 8 backward cumulative schedule (from top layer down)
    S_b = [0.0] * (n + 2)  # S_b[i] = latency back through server layer i
    for i in range(n - 1, -1, -1):
        joins = [tail_b[k] + up_b[k] for k in range(K) if cuts[k][1] == i + 1]
        barrier = max(joins) if joins else 0.0
        S_b[i] = max(S_b[i + 1] + srv_b[i] * n_active[i], barrier)

    L_b = max(S_b[cuts[k][0]] + down_b[k] + head_b[k] for k in range(K))
    return L_f, L_b


def huscf_iteration_latency(cuts: Sequence[Cut],
                            devices: Sequence[DeviceProfile],
                            server: DeviceProfile = PAPER_SERVER,
                            batch: int = 64) -> float:
    """Eq. (10): L_T = L_G^F + L_G^B + 3 (L_D^F + L_D^B)."""
    g_cuts = [(c.g_h, c.g_t) for c in cuts]
    d_cuts = [(c.d_h, c.d_t) for c in cuts]
    gf, gb = _one_net_latency(GEN_LAYER_COSTS, g_cuts, devices, server, batch)
    df, db = _one_net_latency(DISC_LAYER_COSTS, d_cuts, devices, server, batch)
    return gf + gb + 3.0 * (df + db)


# ---------------------------------------------------------------------------
# baseline latency models (paper §6.2 comparisons)
# ---------------------------------------------------------------------------

def _full_flops(costs: Sequence[LayerCost], backward: bool) -> float:
    return _segment_flops(costs, 0, len(costs), backward)


def fedgan_iteration_latency(devices: Sequence[DeviceProfile],
                             batch: int = 64) -> float:
    """Full G+D on every client; slowest dominates. D trained 3x (Eq. 10 logic)."""
    g = _full_flops(GEN_LAYER_COSTS, False) + _full_flops(GEN_LAYER_COSTS, True)
    d = _full_flops(DISC_LAYER_COSTS, False) + _full_flops(DISC_LAYER_COSTS, True)
    per_sample = g + 3.0 * d
    return max(batch * per_sample / dv.flops_per_s for dv in devices)


def hflgan_iteration_latency(devices: Sequence[DeviceProfile],
                             batch: int = 64) -> float:
    """HFL-GAN trains two generators per client (paper §6.2)."""
    g = _full_flops(GEN_LAYER_COSTS, False) + _full_flops(GEN_LAYER_COSTS, True)
    d = _full_flops(DISC_LAYER_COSTS, False) + _full_flops(DISC_LAYER_COSTS, True)
    per_sample = 2.0 * g + 3.0 * d
    return max(batch * per_sample / dv.flops_per_s for dv in devices)


def pflgan_iteration_latency(devices: Sequence[DeviceProfile],
                             batch: int = 64) -> float:
    """PFL-GAN trains the full cGAN locally (plus server-side refinement
    that is off the client critical path); client-side dominates."""
    return fedgan_iteration_latency(devices, batch) * 1.07  # + local cGAN refresh overhead


def mdgan_iteration_latency(devices: Sequence[DeviceProfile],
                            server: DeviceProfile = PAPER_SERVER,
                            batch: int = 64) -> float:
    """MD-GAN: G on server; clients train D only (3 passes) and receive
    synthetic batches (2 downloads: X_d and X_g per iteration)."""
    d = _full_flops(DISC_LAYER_COSTS, False) + _full_flops(DISC_LAYER_COSTS, True)
    img_bytes = 28 * 28 * 4.0
    K = len(devices)
    g_fwd = batch * _full_flops(GEN_LAYER_COSTS, False) / server.flops_per_s
    # server generates for all clients sequentially, then slowest client D step
    client = max(3.0 * batch * d / dv.flops_per_s
                 + 2.0 * batch * img_bytes / dv.rate_bytes_per_s
                 for dv in devices)
    g_bwd = batch * _full_flops(GEN_LAYER_COSTS, True) / server.flops_per_s * K
    return g_fwd * K + client + g_bwd


def fedsplitgan_iteration_latency(devices: Sequence[DeviceProfile],
                                  server: DeviceProfile = PAPER_SERVER,
                                  batch: int = 64) -> float:
    """Federated Split GANs: G on server, D split per device capability
    (single cut, D-head on client). We model the best single-cut split."""
    n = len(DISC_LAYER_COSTS)
    best = None
    for cut in range(1, n):
        total_client = []
        for dv in devices:
            head_f = batch * _segment_flops(DISC_LAYER_COSTS, 0, cut, False) / dv.flops_per_s
            head_b = batch * _segment_flops(DISC_LAYER_COSTS, 0, cut, True) / dv.flops_per_s
            up = batch * DISC_LAYER_COSTS[cut - 1].act_bytes / dv.rate_bytes_per_s
            total_client.append(3.0 * (head_f + head_b + 2.0 * up))
        srv_d = 3.0 * batch * (_segment_flops(DISC_LAYER_COSTS, cut, n, False)
                               + _segment_flops(DISC_LAYER_COSTS, cut, n, True)) / server.flops_per_s
        srv_g = batch * (_full_flops(GEN_LAYER_COSTS, False)
                         + _full_flops(GEN_LAYER_COSTS, True)) / server.flops_per_s
        # synthetic images shipped to clients
        ship = batch * 28 * 28 * 4.0 / min(dv.rate_bytes_per_s for dv in devices)
        t = max(total_client) + srv_d * len(devices) + srv_g + ship
        best = t if best is None else min(best, t)
    return best
