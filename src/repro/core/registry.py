"""Client population registry + per-round cohort sampling.

The paper's pitch is harnessing "many underutilized devices", and the
related federated-GAN literature (EFFGAN, Federated Split GANs —
PAPERS.md) assumes a *registry* of devices far larger than any one
round's participant set: each round samples a cohort of S clients out
of the N registered, trains/aggregates over the cohort, and leaves
everyone else untouched until they are next drawn. ``ClientRegistry``
models exactly that split between *registered* (known to the server:
id, dataset size) and *participating* (sampled this round).

Sampling runs on device from a ``jax.random`` key (a permutation
prefix, so cohort ids are unique), which keeps the fully-fused
federation round free of host<->device syncs — the cohort array feeds
straight into the in-jit cohort weight renormalization
(``kld.cohort_federation_weights_jax``) and the chunk-streamed
aggregation (``federation.FederationPlan``). Determinism: one key, one
cohort; the round-to-round key chain lives with the caller (the
trainer splits its cohort key every ``federate()``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# Module-level jitted bodies (cached per static (n, s)): eagerly,
# jax 0.4's slice/scatter impls dispatch dynamic ops whose index
# operands are host scalars, which trips
# transfer_guard("disallow_explicit") in the otherwise transfer-free
# federation round. Under jit the static bounds compile in.
@functools.partial(jax.jit, static_argnums=(1, 2))
def _sample_sorted_prefix(key, n: int, s: int) -> jnp.ndarray:
    perm = jax.random.permutation(key, n)
    return jnp.sort(jax.lax.slice(perm, (0,), (s,))).astype(jnp.int32)


@functools.partial(jax.jit, static_argnums=(1,))
def _ids_to_mask(ids, n: int) -> jnp.ndarray:
    return jnp.zeros(n, bool).at[ids].set(True)


@dataclasses.dataclass(frozen=True)
class ClientRegistry:
    """The server's view of the registered population.

    ``sizes[k]`` is client k's dataset size (the ``n_k`` of Eq. 15);
    global client ids are the positions 0..N-1, matching the
    ``ProfileGroup.client_ids`` convention everywhere else.
    """
    sizes: np.ndarray                    # [N] int64 dataset sizes

    def __post_init__(self):
        object.__setattr__(self, "sizes",
                           np.asarray(self.sizes, np.int64).reshape(-1))

    @classmethod
    def from_clients(cls, clients: Sequence) -> "ClientRegistry":
        """From ``data.partition.ClientSpec``-likes (anything with
        ``.n``)."""
        return cls(np.array([c.n for c in clients], np.int64))

    @property
    def n_clients(self) -> int:
        return int(self.sizes.shape[0])

    def sample_cohort(self, key, cohort_size: int) -> jnp.ndarray:
        """Sorted unique client ids ``[cohort_size]`` int32, drawn
        without replacement from the registry (a ``jax.random``
        permutation prefix). Jit-compatible; stays on device."""
        n = self.n_clients
        s = int(cohort_size)
        if not 1 <= s <= n:
            raise ValueError(
                f"cohort_size {s} out of range for a registry of {n}")
        return _sample_sorted_prefix(key, n, s)

    def cohort_mask(self, cohort_ids: jnp.ndarray) -> jnp.ndarray:
        """[N] bool participation mask from sampled ids (device)."""
        return _ids_to_mask(cohort_ids, self.n_clients)

    def churn(self, leave: Sequence[int] = (),
              join_sizes: Sequence[int] = ()
              ) -> Tuple["ClientRegistry", List[int]]:
        """Membership churn: ``leave`` = registered ids exiting,
        ``join_sizes`` = dataset sizes of new registrants. Returns the
        post-churn registry plus the id remap ``old_of`` (new global id
        -> old id, -1 for joiners): survivors compact down in
        registration order, joiners append — the convention the trainer
        uses to migrate params/EMA rows across a rebuild."""
        leave_set = {int(c) for c in leave}
        bad = sorted(c for c in leave_set if not 0 <= c < self.n_clients)
        if bad:
            raise ValueError(f"unknown client ids in leave: {bad}")
        old_of = [c for c in range(self.n_clients) if c not in leave_set]
        sizes = [int(self.sizes[o]) for o in old_of]
        for s in join_sizes:
            old_of.append(-1)
            sizes.append(int(s))
        if not sizes:
            raise ValueError("churn would leave an empty registry")
        return ClientRegistry(np.array(sizes, np.int64)), old_of
