"""K-means clustering of discriminator mid-layer activations — paper §4.5.

Pure numpy (runs on the 'server'; K = #clients is small).  k-means++
seeding, Lloyd iterations; the number of clusters is selected by
silhouette score over k in [2, k_max], falling back to k=1 when the
best silhouette is weak (single-domain populations).

The inner assignment step has a Pallas TPU kernel twin
(`repro.kernels.kmeans_assign`) used by the benchmark harness.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


def kmeans_pp_init(x: np.ndarray, k: int, rng: np.random.Generator) -> np.ndarray:
    n = x.shape[0]
    centers = [x[rng.integers(n)]]
    for _ in range(1, k):
        d2 = np.min(((x[:, None, :] - np.array(centers)[None]) ** 2).sum(-1), 1)
        total = d2.sum()
        if total <= 1e-12:
            centers.append(x[rng.integers(n)])
            continue
        probs = d2 / total
        centers.append(x[rng.choice(n, p=probs)])
    return np.array(centers)


def kmeans(x: np.ndarray, k: int, *, iters: int = 50, seed: int = 0
           ) -> Tuple[np.ndarray, np.ndarray, float]:
    """Returns (labels [N], centers [k, D], inertia)."""
    rng = np.random.default_rng(seed)
    if k <= 1:
        center = x.mean(0, keepdims=True)
        inertia = float(((x - center) ** 2).sum())
        return np.zeros(x.shape[0], np.int32), center, inertia
    centers = kmeans_pp_init(x, k, rng)
    labels = np.zeros(x.shape[0], np.int32)
    for _ in range(iters):
        d2 = ((x[:, None, :] - centers[None]) ** 2).sum(-1)
        new_labels = d2.argmin(1).astype(np.int32)
        if np.array_equal(new_labels, labels) and _ > 0:
            break
        labels = new_labels
        for c in range(k):
            mask = labels == c
            if mask.any():
                centers[c] = x[mask].mean(0)
            else:  # re-seed empty cluster at the farthest point
                centers[c] = x[d2.min(1).argmax()]
    inertia = float(((x - centers[labels]) ** 2).sum())
    return labels, centers, inertia


def silhouette(x: np.ndarray, labels: np.ndarray) -> float:
    """Mean silhouette coefficient (euclidean)."""
    n = x.shape[0]
    uniq = np.unique(labels)
    if uniq.size < 2 or n < 3:
        return -1.0
    d = np.sqrt(np.maximum(((x[:, None, :] - x[None]) ** 2).sum(-1), 0.0))
    s = np.zeros(n)
    for i in range(n):
        same = labels == labels[i]
        same[i] = False
        a = d[i][same].mean() if same.any() else 0.0
        bs = [d[i][labels == c].mean() for c in uniq if c != labels[i]]
        b = min(bs)
        s[i] = 0.0 if max(a, b) == 0 else (b - a) / max(a, b)
    return float(s.mean())


@dataclasses.dataclass
class ClusterResult:
    labels: np.ndarray
    centers: np.ndarray
    k: int
    silhouette: float


def cluster_activations(acts: np.ndarray, *, k: Optional[int] = None,
                        k_max: int = 6, seed: int = 0,
                        min_silhouette: float = 0.15) -> ClusterResult:
    """Cluster client activation vectors [K_clients, D].

    If `k` is given, use it (the paper assumes domain count detection);
    otherwise pick k by silhouette, accepting k=1 when separation is weak.
    """
    # standardize (activation scales vary across training)
    mu, sd = acts.mean(0), acts.std(0) + 1e-8
    z = (acts - mu) / sd
    if k is not None:
        labels, centers, _ = kmeans(z, k, seed=seed)
        return ClusterResult(labels, centers, k, silhouette(z, labels))
    best: Optional[ClusterResult] = None
    upper = min(k_max, max(2, acts.shape[0] // 2))
    for kk in range(2, upper + 1):
        labels, centers, _ = kmeans(z, kk, seed=seed)
        sil = silhouette(z, labels)
        if best is None or sil > best.silhouette:
            best = ClusterResult(labels, centers, kk, sil)
    if best is None or best.silhouette < min_silhouette:
        labels, centers, _ = kmeans(z, 1, seed=seed)
        return ClusterResult(labels, centers, 1, 0.0)
    return best
