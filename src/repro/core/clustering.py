"""K-means clustering of discriminator mid-layer activations — paper §4.5.

Two implementations of the same stage-3 procedure:

* the numpy reference (runs on the 'server'; K = #clients is small):
  k-means++ seeding, Lloyd iterations; the number of clusters is
  selected by silhouette score over k in [2, k_max], falling back to
  k=1 when the best silhouette is weak (single-domain populations);
* a jit-compatible JAX twin (``cluster_activations_jax``) used by the
  device-resident clustered round (DESIGN.md §Device-resident
  clustering): the Lloyd loop is a ``lax.scan``, k-means++ seeding
  draws from a ``jax.random`` key, and the assignment step can run the
  Pallas ``kmeans_assign`` kernel behind ``use_kernel``. Every
  candidate k in [2, upper] is unrolled at trace time (``upper`` is
  the static ``k_selection_bound``), so shapes are fixed and the
  function traces once per population size.

Both paths canonicalize labels to first-occurrence order so their
cluster ids are directly comparable (k-means labels are otherwise
arbitrary up to permutation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def kmeans_pp_init(x: np.ndarray, k: int, rng: np.random.Generator) -> np.ndarray:
    n = x.shape[0]
    centers = [x[rng.integers(n)]]
    for _ in range(1, k):
        d2 = np.min(((x[:, None, :] - np.array(centers)[None]) ** 2).sum(-1), 1)
        total = d2.sum()
        if total <= 1e-12:
            centers.append(x[rng.integers(n)])
            continue
        probs = d2 / total
        centers.append(x[rng.choice(n, p=probs)])
    return np.array(centers)


def kmeans(x: np.ndarray, k: int, *, iters: int = 50, seed: int = 0
           ) -> Tuple[np.ndarray, np.ndarray, float]:
    """Returns (labels [N], centers [k, D], inertia)."""
    rng = np.random.default_rng(seed)
    if k <= 1:
        center = x.mean(0, keepdims=True)
        inertia = float(((x - center) ** 2).sum())
        return np.zeros(x.shape[0], np.int32), center, inertia
    centers = kmeans_pp_init(x, k, rng)
    labels = np.zeros(x.shape[0], np.int32)
    for _ in range(iters):
        d2 = ((x[:, None, :] - centers[None]) ** 2).sum(-1)
        new_labels = d2.argmin(1).astype(np.int32)
        if np.array_equal(new_labels, labels) and _ > 0:
            break
        labels = new_labels
        empties = []
        for c in range(k):
            mask = labels == c
            if mask.any():
                centers[c] = x[mask].mean(0)
            else:
                empties.append(c)
        if empties:
            # Re-seed empty clusters at farthest points, measured
            # against the *updated* non-empty centers, excluding points
            # already chosen this pass — the stale pre-update d2 put
            # every empty cluster on the same farthest point, leaving
            # duplicate centers forever.
            valid = [c for c in range(k) if c not in empties]
            d2u = ((x[:, None, :] - centers[valid][None]) ** 2
                   ).sum(-1).min(1)
            for c in empties:
                i = int(d2u.argmax())
                centers[c] = x[i]
                d2u[i] = -np.inf
    inertia = float(((x - centers[labels]) ** 2).sum())
    return labels, centers, inertia


def silhouette(x: np.ndarray, labels: np.ndarray) -> float:
    """Mean silhouette coefficient (euclidean).

    Singleton clusters score s_i = 0 (the standard convention): the
    old a=0 ⇒ s_i=1 treatment handed every lone point a perfect score,
    biasing silhouette k-selection toward fragmenting clusters."""
    n = x.shape[0]
    uniq = np.unique(labels)
    if uniq.size < 2 or n < 3:
        return -1.0
    d = np.sqrt(np.maximum(((x[:, None, :] - x[None]) ** 2).sum(-1), 0.0))
    s = np.zeros(n)
    for i in range(n):
        same = labels == labels[i]
        same[i] = False
        if not same.any():          # singleton cluster
            continue
        a = d[i][same].mean()
        bs = [d[i][labels == c].mean() for c in uniq if c != labels[i]]
        b = min(bs)
        s[i] = 0.0 if max(a, b) == 0 else (b - a) / max(a, b)
    return float(s.mean())


def canonicalize_labels(labels: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Relabel clusters to first-occurrence order. Returns
    (canonical labels, old->new id map over [0, labels.max()])."""
    labels = np.asarray(labels)
    uniq, first = np.unique(labels, return_index=True)
    order = np.argsort(first)            # uniq[order] = appearance order
    remap = np.zeros(int(uniq.max()) + 1, labels.dtype)
    remap[uniq[order]] = np.arange(order.size, dtype=labels.dtype)
    return remap[labels], remap


def k_selection_bound(n_clients: int, k: Optional[int] = None,
                      k_max: int = 6) -> int:
    """Static upper bound on cluster ids out of cluster_activations /
    cluster_activations_jax — the silhouette-selection candidate cap
    (or the forced k). The device round sizes its in-jit weight matrix
    by this bound so the segment count never retraces."""
    if k is not None:
        return max(1, int(k))
    return min(k_max, max(2, n_clients // 2))


@dataclasses.dataclass
class ClusterResult:
    labels: np.ndarray
    centers: np.ndarray
    k: int
    silhouette: float


def cluster_activations(acts: np.ndarray, *, k: Optional[int] = None,
                        k_max: int = 6, seed: int = 0,
                        min_silhouette: float = 0.15) -> ClusterResult:
    """Cluster client activation vectors [K_clients, D].

    If `k` is given, use it (the paper assumes domain count detection);
    otherwise pick k by silhouette, accepting k=1 when separation is weak.
    """
    # standardize (activation scales vary across training)
    mu, sd = acts.mean(0), acts.std(0) + 1e-8
    z = (acts - mu) / sd

    def _canonical(labels, centers):
        new_labels, remap = canonicalize_labels(labels)
        # move the center rows of appearing clusters to their new ids;
        # rows of empty clusters land past them and are never referenced
        new = centers.copy()
        for old in np.unique(labels):
            new[remap[old]] = centers[old]
        return new_labels, new

    if k is not None:
        labels, centers, _ = kmeans(z, k, seed=seed)
        labels, centers = _canonical(labels, centers)
        return ClusterResult(labels, centers, k, silhouette(z, labels))
    best: Optional[ClusterResult] = None
    upper = k_selection_bound(acts.shape[0], k_max=k_max)
    for kk in range(2, upper + 1):
        labels, centers, _ = kmeans(z, kk, seed=seed)
        labels, centers = _canonical(labels, centers)
        sil = silhouette(z, labels)
        if best is None or sil > best.silhouette:
            best = ClusterResult(labels, centers, kk, sil)
    if best is None or best.silhouette < min_silhouette:
        labels, centers, _ = kmeans(z, 1, seed=seed)
        return ClusterResult(labels, centers, 1, 0.0)
    return best


# ---------------------------------------------------------------------------
# JAX twins (device-resident stage 3 — DESIGN.md §Device-resident clustering)
# ---------------------------------------------------------------------------

def canonicalize_labels_jax(labels: jnp.ndarray, num_clusters: int
                            ) -> jnp.ndarray:
    """Traced twin of canonicalize_labels: relabel to first-occurrence
    order. ``num_clusters`` is the static id bound."""
    n = labels.shape[0]
    first = jnp.full(num_clusters, n, jnp.int32)
    first = first.at[labels].min(jnp.arange(n, dtype=jnp.int32))
    # appearance rank; absent clusters (first == n) sort last, stably
    rank = jnp.argsort(jnp.argsort(first))
    return rank[labels].astype(labels.dtype)


def _sq_dists(x: jnp.ndarray, centers: jnp.ndarray) -> jnp.ndarray:
    """[N, M] squared euclidean distances, clipped at 0."""
    d2 = (jnp.sum(x * x, -1)[:, None]
          - 2.0 * x @ centers.T + jnp.sum(centers * centers, -1)[None, :])
    return jnp.maximum(d2, 0.0)


def _assign(x: jnp.ndarray, centers: jnp.ndarray,
            use_kernel: bool) -> jnp.ndarray:
    """argmin_m ||x - c_m||^2 — Pallas kmeans_assign behind use_kernel
    (the ||x||^2 term is constant under argmin either way)."""
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.kmeans_assign(x, centers)
    scores = (-2.0 * x @ centers.T
              + jnp.sum(centers * centers, -1)[None, :])
    return jnp.argmin(scores, axis=1).astype(jnp.int32)


def _kmeans_pp_init_jax(x: jnp.ndarray, k: int, key: jnp.ndarray
                        ) -> jnp.ndarray:
    """k-means++ seeding from a jax PRNG key. Unfilled center slots sit
    at +inf so distance minima only ever see chosen centers."""
    n = x.shape[0]
    key, k0 = jax.random.split(key)
    centers = jnp.full((k,) + x.shape[1:], jnp.inf, x.dtype)
    centers = centers.at[0].set(x[jax.random.randint(k0, (), 0, n)])

    def body(j, carry):
        centers, key = carry
        key, kc = jax.random.split(key)
        d2 = ((x[:, None, :] - centers[None]) ** 2).sum(-1).min(1)
        total = d2.sum()
        # degenerate (all points on chosen centers): uniform draw,
        # matching kmeans_pp_init's total <= 1e-12 fallback
        logits = jnp.where(total > 1e-12,
                           jnp.log(jnp.maximum(d2, 1e-30)),
                           jnp.zeros_like(d2))
        idx = jax.random.categorical(kc, logits)
        return centers.at[j].set(x[idx]), key

    centers, _ = jax.lax.fori_loop(1, k, body, (centers, key))
    return centers


def kmeans_jax(x: jnp.ndarray, k: int, key: jnp.ndarray, *,
               iters: int = 50, use_kernel: bool = False
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Jitted Lloyd loop: returns (labels [N] int32, centers [k, D]).

    ``k``/``iters``/``use_kernel`` are static; the iteration is a
    ``lax.while_loop`` with the numpy loop's convergence test (labels
    stable after the first update) as the in-graph exit condition —
    fixed-trip-count scanning burned ~iters/actual-iters more wall
    than the host path, which early-breaks. The assignment step
    optionally runs the Pallas ``kmeans_assign`` kernel, and empty
    clusters re-seed at distinct farthest points measured against the
    updated centers (the same semantics as the fixed numpy
    ``kmeans``)."""
    n = x.shape[0]
    if k <= 1:
        return (jnp.zeros(n, jnp.int32), jnp.mean(x, 0, keepdims=True))
    centers0 = _kmeans_pp_init_jax(x, k, key)

    def cond(carry):
        _, _, it, done = carry
        return (~done) & (it < iters)

    def body(carry):
        centers, labels, it, _ = carry
        new_labels = _assign(x, centers, use_kernel)
        done = (it > 0) & jnp.all(new_labels == labels)
        onehot = jax.nn.one_hot(new_labels, k, dtype=x.dtype)    # [N, k]
        counts = onehot.sum(0)                                   # [k]
        sums = onehot.T @ x                                      # [k, D]
        new = jnp.where(counts[:, None] > 0,
                        sums / jnp.maximum(counts, 1.0)[:, None], centers)
        # empty-cluster re-seed: farthest points from the *updated*
        # non-empty centers, one distinct point per empty cluster
        d2c = _sq_dists(x, new)
        d2u = jnp.where(counts[None, :] > 0, d2c, jnp.inf).min(1)
        taken = jnp.zeros(n, bool)
        for c in range(k):                       # static unroll, k small
            empty = counts[c] == 0
            idx = jnp.argmax(jnp.where(taken, -jnp.inf, d2u))
            new = new.at[c].set(jnp.where(empty, x[idx], new[c]))
            taken = taken.at[idx].set(taken[idx] | empty)
        # a converged step keeps the previous centers (the numpy loop
        # breaks before its update; the update would be idempotent)
        new = jnp.where(done, centers, new)
        return new, new_labels, it + 1, done

    centers, _, _, _ = jax.lax.while_loop(
        cond, body, (centers0, jnp.zeros(n, jnp.int32),
                     jnp.zeros((), jnp.int32), jnp.zeros((), bool)))
    return _assign(x, centers, use_kernel), centers


def silhouette_jax(x: jnp.ndarray, labels: jnp.ndarray,
                   num_clusters: int) -> jnp.ndarray:
    """Traced twin of ``silhouette`` (singleton clusters score 0);
    ``num_clusters`` is the static id bound. Returns a f32 scalar,
    -1.0 when fewer than two clusters appear or n < 3."""
    n = x.shape[0]
    d = jnp.sqrt(_sq_dists(x, x))
    onehot = jax.nn.one_hot(labels, num_clusters, dtype=x.dtype)  # [N, C]
    counts = onehot.sum(0)                                        # [C]
    sums = d @ onehot                                             # [N, C]
    own = counts[labels]                                          # [N]
    a = sums[jnp.arange(n), labels] / jnp.maximum(own - 1.0, 1.0)
    mean_c = jnp.where(counts[None, :] > 0,
                       sums / jnp.maximum(counts, 1.0)[None, :], jnp.inf)
    mean_c = jnp.where(onehot > 0, jnp.inf, mean_c)   # mask own cluster
    b = mean_c.min(1)
    denom = jnp.maximum(a, b)
    s = jnp.where((own <= 1) | (denom <= 0), 0.0, (b - a) / denom)
    valid = ((counts > 0).sum() >= 2) & (n >= 3)
    return jnp.where(valid, s.mean(), -1.0).astype(jnp.float32)


def cluster_activations_jax(acts: jnp.ndarray, key: jnp.ndarray, *,
                            k: Optional[int] = None, k_max: int = 6,
                            min_silhouette: float = 0.15,
                            iters: int = 50, use_kernel: bool = False
                            ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Device twin of ``cluster_activations``: returns device arrays
    (labels [K] int32, selected k (int32 scalar), silhouette (f32
    scalar)) without leaving the device. Candidate k values unroll at
    trace time up to the static ``k_selection_bound``, so label ids
    stay below that bound and the function traces once per population
    size."""
    K = acts.shape[0]
    mu = acts.mean(0)
    sd = acts.std(0) + 1e-8
    z = ((acts - mu) / sd).astype(jnp.float32)
    if k is not None:
        if k <= 1:
            return (jnp.zeros(K, jnp.int32), jnp.asarray(1, jnp.int32),
                    jnp.asarray(0.0, jnp.float32))
        labels, _ = kmeans_jax(z, k, key, iters=iters, use_kernel=use_kernel)
        labels = canonicalize_labels_jax(labels, k)
        return (labels, jnp.asarray(k, jnp.int32),
                silhouette_jax(z, labels, k))
    upper = k_selection_bound(K, k_max=k_max)
    keys = jax.random.split(key, upper - 1)
    cand_labels, cand_sils = [], []
    for i, kk in enumerate(range(2, upper + 1)):
        labels, _ = kmeans_jax(z, kk, keys[i], iters=iters,
                               use_kernel=use_kernel)
        labels = canonicalize_labels_jax(labels, kk)
        cand_labels.append(labels)
        cand_sils.append(silhouette_jax(z, labels, kk))
    sils = jnp.stack(cand_sils)
    best = jnp.argmax(sils)                      # first max, like the numpy >
    sil = sils[best]
    labels = jnp.stack(cand_labels)[best]
    ok = sil >= min_silhouette
    return (jnp.where(ok, labels, 0).astype(jnp.int32),
            jnp.where(ok, best + 2, 1).astype(jnp.int32),
            jnp.where(ok, sil, 0.0).astype(jnp.float32))
