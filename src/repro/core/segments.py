"""SplitProgram — one compiled representation of a cut configuration
(DESIGN.md §SplitProgram).

The paper's U-shaped split schedule (§4.1/§4.4, Eq. 3-10) used to exist
three separate times in this repo: `huscf.build_net_apply` hand-rolled
the head/server/tail loops for training, `latency_jax` staged the
Eq. 7-8 schedule purely analytically, and `launch/serve.py` never split
at all. This module compiles a cut configuration ONCE into typed
segments — per-group client heads, a sequence of server steps with
explicit join/depart barriers, per-group client tails — and every
consumer executes or analyzes that shared program:

* `make_apply` — the training/eval executor. Bit-exact with the legacy
  `build_net_apply` loops by construction: it replays the identical op
  sequence (vmapped heads in group order, per-server-layer concat over
  the active groups in group order, the same splits / middle capture /
  ghost-BN averaging), just driven by the compiled `ServerStep` table
  instead of re-deriving activity from cuts inline.
* `program_net_latency` / `program_iteration_latency` — the Eq. 7-10
  analytic model evaluated from the program structure (host f64,
  exactly equal to `latency.huscf_iteration_latency`), plus
  `program_forward_latency` for serving (one U-shaped forward pass).
  `join_barrier_scan` is the Eq. 7/8 recurrence as a `lax.scan`,
  shared with `core.latency_jax`.
* the `launch/serve_split.py` engine — executes `make_apply` in eval
  mode over a bucket-padded cohort (`SplitProgram.buckets`, power-of-
  two request counts per cut) so a churning request mix reuses one
  compiled program per bucket signature.

Join barriers live in the *executor/analyzer*, not the model: a layer
`apply(params, x, train)` is a pure local function; which clients'
activations concatenate before it (Eq. 7's join) and which peel off
after it (Eq. 8's depart) is scheduling, decided entirely by the cut
configuration. Baking it into the model would fuse topology into
weights; the program table keeps one model definition serving every
cut mix.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.latency import DeviceProfile, PAPER_SERVER
from repro.core.splitting import (ProfileGroup, bucket_size, layer_pair,
                                  server_union_span)
from repro.models.gan import (DISC_LAYER_COSTS, DISC_LAYER_DEFS,
                              GEN_LAYER_COSTS, GEN_LAYER_DEFS)
from repro.sharding.policy import maybe_shard

Array = jnp.ndarray

NET_LAYER_DEFS = {"G": GEN_LAYER_DEFS, "D": DISC_LAYER_DEFS}
NET_LAYER_COSTS = {"G": GEN_LAYER_COSTS, "D": DISC_LAYER_COSTS}


# ---------------------------------------------------------------------------
# program structure
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Segment:
    """One typed client-side layer range of the program."""
    kind: str                    # "head" | "tail"
    gname: str                   # owning profile group
    start: int                   # half-open layer range [start, stop)
    stop: int


@dataclasses.dataclass(frozen=True)
class ServerStep:
    """One server layer of the program with its barrier structure.

    ``active``: groups whose span covers this layer, in canonical group
    order — the executor concatenates their activations in exactly this
    order and the latency model weights the layer by their sizes.
    ``joins``: groups whose head ends here (Eq. 7 forward barrier — the
    server cannot start this layer before their uplink lands).
    ``departs``: groups whose server span ends after this layer (Eq. 8
    reverse barrier / forward downlink — their activations peel off to
    the client tail).
    """
    layer: int
    active: Tuple[str, ...]
    joins: Tuple[str, ...]
    departs: Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class SplitProgram:
    """Compiled cut configuration for one network (G or D).

    Parallel tuples indexed by group position (canonical group order):
    ``group_names``, ``sizes`` (client counts), ``buckets`` (sizes
    rounded up to powers of two — the padded-cohort compile shapes),
    ``cuts`` ((head_end, tail_start) pairs for this net).
    """
    net: str
    n_layers: int
    middle: int
    group_names: Tuple[str, ...]
    sizes: Tuple[int, ...]
    buckets: Tuple[int, ...]
    cuts: Tuple[Tuple[int, int], ...]
    heads: Tuple[Segment, ...]
    steps: Tuple[ServerStep, ...]
    tails: Tuple[Segment, ...]

    def index_of(self, gname: str) -> int:
        return self.group_names.index(gname)

    def cut_of(self, gname: str) -> Tuple[int, int]:
        return self.cuts[self.index_of(gname)]

    def size_of(self, gname: str) -> int:
        return self.sizes[self.index_of(gname)]

    def bucket_of(self, gname: str) -> int:
        return self.buckets[self.index_of(gname)]

    def server_span(self) -> Tuple[int, ...]:
        return tuple(s.layer for s in self.steps)

    def shape_key(self, padded: bool = False) -> Tuple:
        """Hashable compile-shape fingerprint: everything a traced
        executor bakes in. With ``padded=True`` group sizes enter as
        their buckets, so any population whose per-group counts stay
        within the buckets maps to the same key (and may share one
        compiled program)."""
        counts = self.buckets if padded else self.sizes
        return (self.net, self.n_layers,
                tuple(zip(self.group_names, self.cuts, counts)))


def compile_split_program(groups: Sequence[ProfileGroup], net: str,
                          n_layers: Optional[int] = None) -> SplitProgram:
    """Compile the (groups, net) cut configuration into a SplitProgram.

    Pure host-side structure derivation — cheap enough to run per
    rebuild; the expensive artifact is the traced executor, which is
    keyed on `shape_key` by its consumers.
    """
    if n_layers is None:
        n_layers = len(NET_LAYER_DEFS[net])
    names = tuple(g.name for g in groups)
    cuts = tuple(layer_pair(g.cut, net) for g in groups)
    sizes = tuple(g.size for g in groups)
    span = server_union_span(groups, net, n_layers)
    steps = []
    for l in span:
        active = tuple(n for n, (h, t) in zip(names, cuts) if h <= l < t)
        joins = tuple(n for n, (h, _) in zip(names, cuts) if h == l)
        departs = tuple(n for n, (_, t) in zip(names, cuts) if t == l + 1)
        steps.append(ServerStep(l, active, joins, departs))
    return SplitProgram(
        net=net, n_layers=n_layers, middle=n_layers // 2,
        group_names=names, sizes=sizes,
        buckets=tuple(bucket_size(s) for s in sizes), cuts=cuts,
        heads=tuple(Segment("head", n, 0, h)
                    for n, (h, _) in zip(names, cuts)),
        steps=tuple(steps),
        tails=tuple(Segment("tail", n, t, n_layers)
                    for n, (_, t) in zip(names, cuts)))


# ---------------------------------------------------------------------------
# client-side segment passes (shared with the legacy huscf oracle)
# ---------------------------------------------------------------------------

def head_pass(defs, params: Dict[str, Any], x, stop: int, train: bool):
    new = {}
    for l in range(stop):
        x, new[str(l)] = defs[l][1](params[str(l)], x, train)
    return x, new


def tail_pass(defs, params: Dict[str, Any], x, start: int, n: int,
              train: bool):
    new = {}
    for l in range(start, n):
        x, new[str(l)] = defs[l][1](params[str(l)], x, train)
    return x, new


# ---------------------------------------------------------------------------
# the executor
# ---------------------------------------------------------------------------

def make_apply(program: SplitProgram, capture_middle: bool = False,
               concat_groups: bool = True) -> Callable:
    """The U-shaped split executor for one compiled program.

    Returns ``apply(client_params, server_params, inputs, train) ->
    (outputs {gname: [K,b,...]}, new_client, new_server, middles)``
    with ``inputs`` = {gname: tuple of per-client-stacked arrays fed to
    layer 0} — the same contract as `huscf.build_net_apply`, which now
    delegates here.

    concat_groups=True is the paper-faithful schedule (the server
    concatenates all active clients' activations per layer — the Eq. 7
    join — so BatchNorm stats span the population). False is the
    beyond-paper TPU optimization (EXPERIMENTS.md §Perf iteration 5):
    each group flows through the shared server weights separately,
    keeping the client-sharded layout intact at the cost of ghost-BN
    (per-group) statistics.
    """
    defs = NET_LAYER_DEFS[program.net]
    n = program.n_layers
    middle = program.middle

    def apply(client_params, server_params, inputs, train: bool):
        new_client = {name: dict(client_params[name])
                      for name in program.group_names}
        new_server = dict(server_params)
        # --- client heads (vmapped over the group's stacked clients)
        bufs: Dict[str, Array] = {}
        shapes: Dict[str, Tuple[int, int]] = {}
        for seg in program.heads:
            head_fn = functools.partial(head_pass, defs, stop=seg.stop,
                                        train=train)
            acts, upd = jax.vmap(lambda p, *xs: head_fn(p, xs))(
                client_params[seg.gname], *inputs[seg.gname])
            new_client[seg.gname].update(upd)
            k, b = acts.shape[0], acts.shape[1]
            shapes[seg.gname] = (k, b)
            bufs[seg.gname] = maybe_shard(
                acts.reshape((k * b,) + acts.shape[2:]), "rows")
        # --- server trunk: one ServerStep per layer, joins/departs
        #     resolved at compile time (paper Fig. 7)
        outs: Dict[str, Array] = {}
        middles: Dict[str, Array] = {}
        for step in program.steps:
            l = step.layer
            if concat_groups:
                xs = [bufs[gname] for gname in step.active]
                sizes = [x.shape[0] for x in xs]
                x = jnp.concatenate(xs, 0) if len(xs) > 1 else xs[0]
                x, new_server[str(l)] = defs[l][1](server_params[str(l)], x,
                                                   train)
                parts = (jnp.split(x, list(np.cumsum(sizes)[:-1]), 0)
                         if len(xs) > 1 else [x])
            else:
                # per-group pass through the SAME shared server weights;
                # BN state updates merge by equal-weight averaging.
                parts, bn_updates = [], []
                for gname in step.active:
                    y, upd = defs[l][1](server_params[str(l)],
                                        bufs[gname], train)
                    parts.append(y)
                    bn_updates.append(upd)
                new_server[str(l)] = jax.tree_util.tree_map(
                    lambda *xs: sum(xs) / len(xs), *bn_updates)
            for gname, part in zip(step.active, parts):
                bufs[gname] = maybe_shard(part, "rows")
                if capture_middle and l == middle:
                    k, b = shapes[gname]
                    mid = part.reshape((k, b) + part.shape[1:])
                    middles[gname] = jnp.mean(
                        mid.reshape(k, b, -1).astype(jnp.float32), axis=1)
                if gname in step.departs:
                    outs[gname] = bufs[gname]
        # --- client tails (vmapped)
        results: Dict[str, Array] = {}
        for seg in program.tails:
            k, b = shapes[seg.gname]
            x = outs[seg.gname]
            x = x.reshape((k, b) + x.shape[1:])
            tail_fn = functools.partial(tail_pass, defs, start=seg.start,
                                        n=n, train=train)
            y, upd = jax.vmap(tail_fn)(client_params[seg.gname], x)
            new_client[seg.gname].update(upd)
            results[seg.gname] = y
        return results, new_client, new_server, middles

    return apply


# ---------------------------------------------------------------------------
# Eq. 7/8 schedule machinery (shared with latency_jax)
# ---------------------------------------------------------------------------

def join_barrier_scan(terms: Array, barriers: Array,
                      reverse: bool = False) -> Array:
    """Eq. 7/8 cumulative server schedule as a `lax.scan` recurrence:
    ``S[i+1] = max(S[i] + terms[i], barriers[i])`` (forward), swept
    top-down with ``reverse=True`` for the backward Eq. 8. Returns the
    [n] cumulative values in layer order.
    """
    def sched(s, x):
        a, bar = x
        s = jnp.maximum(s + a, bar)
        return s, s

    _, out = jax.lax.scan(sched, jnp.float32(0.0), (terms, barriers),
                          reverse=reverse)
    return out


# ---------------------------------------------------------------------------
# analytic latency evaluated from the program structure (host f64)
# ---------------------------------------------------------------------------

def _seg_flops(costs, start: int, stop: int, backward: bool) -> float:
    key = "flops_bwd" if backward else "flops_fwd"
    return sum(getattr(c, key) for c in costs[start:stop])


def program_net_latency(program: SplitProgram,
                        profiles: Mapping[str, DeviceProfile],
                        server: DeviceProfile = PAPER_SERVER,
                        batch: int = 64,
                        counts: Optional[Mapping[str, float]] = None
                        ) -> Tuple[float, float]:
    """(L_f, L_b) — Eq. 7-9 for one network from the program structure.

    ``profiles`` maps group name -> DeviceProfile. Exactly equal to
    `latency._one_net_latency` over the member-expanded population: all
    members of a group are identical, so the per-layer occupancy
    collapses to size-weighted sums and the barrier/completion maxes
    are unchanged. ``counts`` overrides the per-group multiplicities
    (serving cohorts: number of requests per cut instead of the
    training population size).
    """
    costs = NET_LAYER_COSTS[program.net]
    n = program.n_layers
    b = float(batch)
    names = program.group_names
    mult = {g: float(program.size_of(g)) if counts is None
            else float(counts[g]) for g in names}

    head_f, head_b, tail_f, tail_b = {}, {}, {}, {}
    up_f, up_b, down_f, down_b = {}, {}, {}, {}
    for g, (h, t) in zip(names, program.cuts):
        dev = profiles[g]
        head_f[g] = b * _seg_flops(costs, 0, h, False) / dev.flops_per_s
        head_b[g] = b * _seg_flops(costs, 0, h, True) / dev.flops_per_s
        tail_f[g] = b * _seg_flops(costs, t, n, False) / dev.flops_per_s
        tail_b[g] = b * _seg_flops(costs, t, n, True) / dev.flops_per_s
        up_f[g] = b * costs[h - 1].act_bytes / dev.rate_bytes_per_s
        up_b[g] = b * costs[t - 1].act_bytes / dev.rate_bytes_per_s
        down_f[g] = b * costs[t - 1].act_bytes / server.rate_bytes_per_s
        down_b[g] = b * costs[h - 1].act_bytes / server.rate_bytes_per_s

    srv_f = [b * costs[i].flops_fwd / server.flops_per_s for i in range(n)]
    srv_b = [b * costs[i].flops_bwd / server.flops_per_s for i in range(n)]
    step_of = {s.layer: s for s in program.steps}

    # Eq. 7 forward schedule: joins gate the layer, occupancy scales it
    S_f = [0.0] * (n + 1)
    for i in range(n):
        step = step_of.get(i)
        joins = ([head_f[g] + up_f[g] for g in step.joins]
                 if step is not None else [])
        n_act = (sum(mult[g] for g in step.active)
                 if step is not None else 0.0)
        barrier = max(joins) if joins else 0.0
        S_f[i + 1] = max(S_f[i] + srv_f[i] * n_act, barrier)
    L_f = max(S_f[t] + down_f[g] + tail_f[g]
              for g, (_, t) in zip(names, program.cuts))

    # Eq. 8 backward schedule, top layer down
    S_b = [0.0] * (n + 2)
    for i in range(n - 1, -1, -1):
        step = step_of.get(i)
        joins = ([tail_b[g] + up_b[g] for g in step.departs]
                 if step is not None else [])
        n_act = (sum(mult[g] for g in step.active)
                 if step is not None else 0.0)
        barrier = max(joins) if joins else 0.0
        S_b[i] = max(S_b[i + 1] + srv_b[i] * n_act, barrier)
    L_b = max(S_b[h] + down_b[g] + head_b[g]
              for g, (h, _) in zip(names, program.cuts))
    return L_f, L_b


def program_iteration_latency(prog_g: SplitProgram, prog_d: SplitProgram,
                              profiles: Mapping[str, DeviceProfile],
                              server: DeviceProfile = PAPER_SERVER,
                              batch: int = 64) -> float:
    """Eq. 10 from two compiled programs: L = gf + gb + 3 (df + db)."""
    gf, gb = program_net_latency(prog_g, profiles, server, batch)
    df, db = program_net_latency(prog_d, profiles, server, batch)
    return gf + gb + 3.0 * (df + db)


def program_forward_latency(program: SplitProgram,
                            profiles: Mapping[str, DeviceProfile],
                            server: DeviceProfile = PAPER_SERVER,
                            batch: int = 64,
                            counts: Optional[Mapping[str, float]] = None
                            ) -> float:
    """Serving prediction: one U-shaped forward pass (Eq. 7 + Eq. 9
    completion, no backward). ``counts`` = requests per cut."""
    l_f, _ = program_net_latency(program, profiles, server, batch,
                                 counts=counts)
    return l_f
