"""Activation-based KLD scoring — paper §4.5, Eq. (13)-(15).

P_k   = softmax(mean middle-layer discriminator activation of client k)
P_j,k = leave-one-out mean of P over client k's cluster
KLD_k = KL(P_k || P_j,k)
s_k   = n_k exp(-beta KLD_k) / sum_{j in cluster} n_j exp(-beta KLD_j)

Also provides the label-distribution-based variant (FeGAN-style,
paper §6.3 comparison) which shares the same weighting equation but
feeds label histograms instead of activations.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def softmax_np(x: np.ndarray, axis: int = -1) -> np.ndarray:
    x = x - x.max(axis=axis, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=axis, keepdims=True)


def kl_divergence(p: np.ndarray, q: np.ndarray, eps: float = 1e-12) -> float:
    """Eq. (2)."""
    p = np.clip(p, eps, None)
    q = np.clip(q, eps, None)
    return float(np.sum(p * np.log(p / q)))


def activation_distributions(acts: np.ndarray) -> np.ndarray:
    """Eq. (13): P_k = softmax(alpha_k)."""
    return softmax_np(acts.astype(np.float64), axis=-1)


def cluster_klds(P: np.ndarray, labels: np.ndarray) -> np.ndarray:
    """Eq. (14) leave-one-out cluster mean + Eq. (2) KLD per client."""
    K = P.shape[0]
    klds = np.zeros(K)
    for k in range(K):
        same = np.flatnonzero(labels == labels[k])
        others = same[same != k]
        if others.size == 0:
            klds[k] = 0.0
            continue
        P_j = P[others].sum(0) / others.size
        klds[k] = kl_divergence(P[k], P_j)
    return klds


def federation_weights(klds: np.ndarray, sizes: np.ndarray,
                       labels: np.ndarray, beta: float = 150.0) -> np.ndarray:
    """Eq. (15): within-cluster normalized s_k. Returns [K] weights that
    sum to 1 *within each cluster*."""
    raw = sizes.astype(np.float64) * np.exp(-beta * klds)
    out = np.zeros_like(raw)
    for c in np.unique(labels):
        mask = labels == c
        denom = raw[mask].sum()
        out[mask] = raw[mask] / denom if denom > 0 else 1.0 / mask.sum()
    return out


def global_weights(klds: np.ndarray, sizes: np.ndarray,
                   beta: float = 150.0) -> np.ndarray:
    """Eq. (15) applied globally (server-side segments, paper §4.5 end)."""
    raw = sizes.astype(np.float64) * np.exp(-beta * klds)
    return raw / raw.sum()


def activation_weights(acts: np.ndarray, sizes: np.ndarray,
                       labels: np.ndarray, beta: float = 150.0
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """End-to-end Eq. 13-15: returns (intra-cluster weights, klds)."""
    P = activation_distributions(acts)
    klds = cluster_klds(P, labels)
    return federation_weights(klds, sizes, labels, beta), klds


def label_weights(label_hists: np.ndarray, sizes: np.ndarray,
                  labels: np.ndarray, beta: float = 150.0
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """FeGAN-style label-distribution KLD (privacy-leaking baseline,
    paper §6.3). label_hists: [K, num_classes] counts."""
    P = label_hists.astype(np.float64)
    P = P / np.clip(P.sum(-1, keepdims=True), 1e-12, None)
    klds = cluster_klds(P, labels)
    return federation_weights(klds, sizes, labels, beta), klds
