"""Activation-based KLD scoring — paper §4.5, Eq. (13)-(15).

P_k   = softmax(mean middle-layer discriminator activation of client k)
P_j,k = leave-one-out mean of P over client k's cluster
KLD_k = KL(P_k || P_j,k)
s_k   = n_k exp(-beta KLD_k) / sum_{j in cluster} n_j exp(-beta KLD_j)

Eq. (15) is computed in **log-space** (softmax of ``log n_k − beta
KLD_k`` within each cluster): the literal ``n_k exp(-beta KLD_k)``
underflows to all-zero at the paper's beta=150 for moderate KLDs,
which silently discarded the sizes and fell back to *uniform* weights.
The log-space form is exact where the literal form doesn't underflow
and stays size-weighted in the degenerate limit.

Also provides the label-distribution-based variant (FeGAN-style,
paper §6.3 comparison) which shares the same weighting equation but
feeds label histograms instead of activations, jit-compatible JAX
twins (``*_jax``) of the Eq. 13-15 chain for the device-resident
clustered round (DESIGN.md §Device-resident clustering), and the
cohort-renormalized variants (``cohort_federation_weights[_jax]``)
used when only a sampled cohort of the registered population
participates in a round (core/registry.py, DESIGN.md §Chunk-streamed
aggregation).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def softmax_np(x: np.ndarray, axis: int = -1) -> np.ndarray:
    x = x - x.max(axis=axis, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=axis, keepdims=True)


def kl_divergence(p: np.ndarray, q: np.ndarray, eps: float = 1e-12) -> float:
    """Eq. (2)."""
    p = np.clip(p, eps, None)
    q = np.clip(q, eps, None)
    return float(np.sum(p * np.log(p / q)))


def activation_distributions(acts: np.ndarray) -> np.ndarray:
    """Eq. (13): P_k = softmax(alpha_k)."""
    return softmax_np(acts.astype(np.float64), axis=-1)


def cluster_klds(P: np.ndarray, labels: np.ndarray) -> np.ndarray:
    """Eq. (14) leave-one-out cluster mean + Eq. (2) KLD per client."""
    K = P.shape[0]
    klds = np.zeros(K)
    for k in range(K):
        same = np.flatnonzero(labels == labels[k])
        others = same[same != k]
        if others.size == 0:
            klds[k] = 0.0
            continue
        P_j = P[others].sum(0) / others.size
        klds[k] = kl_divergence(P[k], P_j)
    return klds


def _logits(klds: np.ndarray, sizes: np.ndarray, beta: float) -> np.ndarray:
    """log n_k − beta KLD_k, the log of Eq. 15's unnormalized s_k."""
    return (np.log(np.maximum(sizes.astype(np.float64), 1e-300))
            - beta * np.asarray(klds, np.float64))


def _softmax_masked(logits: np.ndarray, mask: np.ndarray) -> np.ndarray:
    l = logits[mask]
    e = np.exp(l - l.max())
    return e / e.sum()


def federation_weights(klds: np.ndarray, sizes: np.ndarray,
                       labels: np.ndarray, beta: float = 150.0) -> np.ndarray:
    """Eq. (15): within-cluster normalized s_k. Returns [K] weights that
    sum to 1 *within each cluster*.

    Computed as a log-space softmax of ``log n_k − beta KLD_k`` per
    cluster: ``n_k exp(-beta KLD_k)`` underflows to all-zero at
    beta=150 for KLDs past ~5, and the old ``denom > 0`` fallback then
    silently dropped the sizes and went uniform."""
    logits = _logits(klds, sizes, beta)
    out = np.zeros_like(logits)
    for c in np.unique(labels):
        mask = labels == c
        out[mask] = _softmax_masked(logits, mask)
    return out


def global_weights(klds: np.ndarray, sizes: np.ndarray,
                   beta: float = 150.0) -> np.ndarray:
    """Eq. (15) applied globally (server-side segments, paper §4.5 end).
    Log-space for the same underflow reason as federation_weights."""
    logits = _logits(klds, sizes, beta)
    return _softmax_masked(logits, np.ones(len(logits), bool))


def cohort_federation_weights(klds: np.ndarray, sizes: np.ndarray,
                              labels: np.ndarray, cohort: np.ndarray,
                              beta: float = 150.0) -> np.ndarray:
    """Eq. (15) renormalized over a sampled *cohort*: within each
    cluster the softmax runs over the cohort members only, so the
    participating clients' weights sum to 1 per (cluster ∩ cohort)
    and every non-member gets exactly 0 (it contributes nothing to —
    and receives nothing from — the round; see core/registry.py).

    Same log-space form as ``federation_weights`` (softmax of
    ``log n_k − beta KLD_k``), so beta=150 cannot underflow the sizes
    away; a singleton cohort member in a cluster degenerates to
    weight 1.0. ``cohort``: [K] bool participation mask."""
    logits = _logits(klds, sizes, beta)
    cohort = np.asarray(cohort, bool)
    out = np.zeros_like(logits)
    for c in np.unique(labels[cohort]):
        mask = (labels == c) & cohort
        out[mask] = _softmax_masked(logits, mask)
    return out


def activation_weights(acts: np.ndarray, sizes: np.ndarray,
                       labels: np.ndarray, beta: float = 150.0
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """End-to-end Eq. 13-15: returns (intra-cluster weights, klds)."""
    P = activation_distributions(acts)
    klds = cluster_klds(P, labels)
    return federation_weights(klds, sizes, labels, beta), klds


def label_weights(label_hists: np.ndarray, sizes: np.ndarray,
                  labels: np.ndarray, beta: float = 150.0
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """FeGAN-style label-distribution KLD (privacy-leaking baseline,
    paper §6.3). label_hists: [K, num_classes] counts."""
    P = label_hists.astype(np.float64)
    P = P / np.clip(P.sum(-1, keepdims=True), 1e-12, None)
    klds = cluster_klds(P, labels)
    return federation_weights(klds, sizes, labels, beta), klds


# ---------------------------------------------------------------------------
# JAX twins (device-resident stage 4 — DESIGN.md §Device-resident clustering)
# ---------------------------------------------------------------------------

def cluster_klds_jax(P: jnp.ndarray, labels: jnp.ndarray,
                     num_clusters: int, eps: float = 1e-12) -> jnp.ndarray:
    """Traced twin of cluster_klds: Eq. (14) leave-one-out cluster mean
    + Eq. (2) KLD per client. ``num_clusters`` is the static label-id
    bound; singleton clusters score 0 like the numpy path."""
    onehot = jax.nn.one_hot(labels, num_clusters, dtype=P.dtype)   # [K, C]
    counts = onehot.sum(0)                                         # [C]
    csum = onehot.T @ P                                            # [C, F]
    own = counts[labels]                                           # [K]
    loo = (csum[labels] - P) / jnp.maximum(own - 1.0, 1.0)[:, None]
    p = jnp.clip(P, eps, None)
    q = jnp.clip(loo, eps, None)
    kld = jnp.sum(p * (jnp.log(p) - jnp.log(q)), axis=-1)
    return jnp.where(own > 1, kld, 0.0)


def federation_weights_jax(klds: jnp.ndarray, sizes: jnp.ndarray,
                           labels: jnp.ndarray, num_clusters: int,
                           beta: float = 150.0) -> jnp.ndarray:
    """Traced twin of federation_weights: within-cluster log-space
    softmax of ``log n_k − beta KLD_k`` via one-hot segment reductions
    (no host loop over cluster ids)."""
    onehot = jax.nn.one_hot(labels, num_clusters, dtype=jnp.float32)
    logits = (jnp.log(jnp.maximum(sizes.astype(jnp.float32), 1e-30))
              - beta * klds.astype(jnp.float32))
    seg_max = jnp.where(onehot > 0, logits[:, None], -jnp.inf).max(0)  # [C]
    e = jnp.exp(logits - seg_max[labels])
    denom = onehot.T @ e                                               # [C]
    return e / denom[labels]


def cohort_federation_weights_jax(klds: jnp.ndarray, sizes: jnp.ndarray,
                                  labels: jnp.ndarray,
                                  cohort_mask: jnp.ndarray,
                                  num_clusters: int,
                                  beta: float = 150.0) -> jnp.ndarray:
    """Traced twin of ``cohort_federation_weights``: within-cluster
    log-space softmax restricted to the cohort, via masked one-hot
    segment reductions. Non-members (and members of clusters with an
    empty cohort) come out exactly 0; the seg-max shift is guarded so
    an empty (cluster ∩ cohort) never produces a NaN."""
    m = cohort_mask.astype(bool)
    onehot = (jax.nn.one_hot(labels, num_clusters, dtype=jnp.float32)
              * m[:, None].astype(jnp.float32))                    # [K, C]
    logits = (jnp.log(jnp.maximum(sizes.astype(jnp.float32), 1e-30))
              - beta * klds.astype(jnp.float32))
    masked = jnp.where(onehot > 0, logits[:, None], -jnp.inf)
    seg_max = masked.max(0)                                        # [C]
    seg_max_safe = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    shifted = jnp.where(m, logits - seg_max_safe[labels], -jnp.inf)
    e = jnp.exp(shifted)                                           # [K]
    denom = onehot.T @ e                                           # [C]
    d = denom[labels]
    return jnp.where(m & (d > 0), e / jnp.where(d > 0, d, 1.0), 0.0)


def activation_weights_jax(acts: jnp.ndarray, sizes: jnp.ndarray,
                           labels: jnp.ndarray, num_clusters: int,
                           beta: float = 150.0,
                           cohort_mask: jnp.ndarray = None
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """End-to-end Eq. 13-15 on device: returns (intra-cluster weights,
    klds) as device arrays. f32 (the numpy oracle runs f64 — agreement
    is to fp tolerance, amplified by beta in the weights).

    ``cohort_mask`` (optional [K] bool) renormalizes the Eq.-15
    weights over the sampled cohort instead of the whole cluster; the
    KLDs themselves stay full-cluster (Eq. 14's leave-one-out mean is
    over the cluster the server clustered, participation only gates
    who synchronizes this round — DESIGN.md §Chunk-streamed
    aggregation)."""
    P = jax.nn.softmax(acts.astype(jnp.float32), axis=-1)
    klds = cluster_klds_jax(P, labels, num_clusters)
    if cohort_mask is not None:
        w = cohort_federation_weights_jax(klds, sizes, labels, cohort_mask,
                                          num_clusters, beta)
    else:
        w = federation_weights_jax(klds, sizes, labels, num_clusters, beta)
    return w, klds
