"""Activation-based KLD scoring — paper §4.5, Eq. (13)-(15).

P_k   = softmax(mean middle-layer discriminator activation of client k)
P_j,k = leave-one-out mean of P over client k's cluster
KLD_k = KL(P_k || P_j,k)
s_k   = n_k exp(-beta KLD_k) / sum_{j in cluster} n_j exp(-beta KLD_j)

Eq. (15) is computed in **log-space** (softmax of ``log n_k − beta
KLD_k`` within each cluster): the literal ``n_k exp(-beta KLD_k)``
underflows to all-zero at the paper's beta=150 for moderate KLDs,
which silently discarded the sizes and fell back to *uniform* weights.
The log-space form is exact where the literal form doesn't underflow
and stays size-weighted in the degenerate limit.

Also provides the label-distribution-based variant (FeGAN-style,
paper §6.3 comparison) which shares the same weighting equation but
feeds label histograms instead of activations, and jit-compatible JAX
twins (``*_jax``) of the Eq. 13-15 chain for the device-resident
clustered round (DESIGN.md §Device-resident clustering).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def softmax_np(x: np.ndarray, axis: int = -1) -> np.ndarray:
    x = x - x.max(axis=axis, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=axis, keepdims=True)


def kl_divergence(p: np.ndarray, q: np.ndarray, eps: float = 1e-12) -> float:
    """Eq. (2)."""
    p = np.clip(p, eps, None)
    q = np.clip(q, eps, None)
    return float(np.sum(p * np.log(p / q)))


def activation_distributions(acts: np.ndarray) -> np.ndarray:
    """Eq. (13): P_k = softmax(alpha_k)."""
    return softmax_np(acts.astype(np.float64), axis=-1)


def cluster_klds(P: np.ndarray, labels: np.ndarray) -> np.ndarray:
    """Eq. (14) leave-one-out cluster mean + Eq. (2) KLD per client."""
    K = P.shape[0]
    klds = np.zeros(K)
    for k in range(K):
        same = np.flatnonzero(labels == labels[k])
        others = same[same != k]
        if others.size == 0:
            klds[k] = 0.0
            continue
        P_j = P[others].sum(0) / others.size
        klds[k] = kl_divergence(P[k], P_j)
    return klds


def _logits(klds: np.ndarray, sizes: np.ndarray, beta: float) -> np.ndarray:
    """log n_k − beta KLD_k, the log of Eq. 15's unnormalized s_k."""
    return (np.log(np.maximum(sizes.astype(np.float64), 1e-300))
            - beta * np.asarray(klds, np.float64))


def _softmax_masked(logits: np.ndarray, mask: np.ndarray) -> np.ndarray:
    l = logits[mask]
    e = np.exp(l - l.max())
    return e / e.sum()


def federation_weights(klds: np.ndarray, sizes: np.ndarray,
                       labels: np.ndarray, beta: float = 150.0) -> np.ndarray:
    """Eq. (15): within-cluster normalized s_k. Returns [K] weights that
    sum to 1 *within each cluster*.

    Computed as a log-space softmax of ``log n_k − beta KLD_k`` per
    cluster: ``n_k exp(-beta KLD_k)`` underflows to all-zero at
    beta=150 for KLDs past ~5, and the old ``denom > 0`` fallback then
    silently dropped the sizes and went uniform."""
    logits = _logits(klds, sizes, beta)
    out = np.zeros_like(logits)
    for c in np.unique(labels):
        mask = labels == c
        out[mask] = _softmax_masked(logits, mask)
    return out


def global_weights(klds: np.ndarray, sizes: np.ndarray,
                   beta: float = 150.0) -> np.ndarray:
    """Eq. (15) applied globally (server-side segments, paper §4.5 end).
    Log-space for the same underflow reason as federation_weights."""
    logits = _logits(klds, sizes, beta)
    return _softmax_masked(logits, np.ones(len(logits), bool))


def activation_weights(acts: np.ndarray, sizes: np.ndarray,
                       labels: np.ndarray, beta: float = 150.0
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """End-to-end Eq. 13-15: returns (intra-cluster weights, klds)."""
    P = activation_distributions(acts)
    klds = cluster_klds(P, labels)
    return federation_weights(klds, sizes, labels, beta), klds


def label_weights(label_hists: np.ndarray, sizes: np.ndarray,
                  labels: np.ndarray, beta: float = 150.0
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """FeGAN-style label-distribution KLD (privacy-leaking baseline,
    paper §6.3). label_hists: [K, num_classes] counts."""
    P = label_hists.astype(np.float64)
    P = P / np.clip(P.sum(-1, keepdims=True), 1e-12, None)
    klds = cluster_klds(P, labels)
    return federation_weights(klds, sizes, labels, beta), klds


# ---------------------------------------------------------------------------
# JAX twins (device-resident stage 4 — DESIGN.md §Device-resident clustering)
# ---------------------------------------------------------------------------

def cluster_klds_jax(P: jnp.ndarray, labels: jnp.ndarray,
                     num_clusters: int, eps: float = 1e-12) -> jnp.ndarray:
    """Traced twin of cluster_klds: Eq. (14) leave-one-out cluster mean
    + Eq. (2) KLD per client. ``num_clusters`` is the static label-id
    bound; singleton clusters score 0 like the numpy path."""
    onehot = jax.nn.one_hot(labels, num_clusters, dtype=P.dtype)   # [K, C]
    counts = onehot.sum(0)                                         # [C]
    csum = onehot.T @ P                                            # [C, F]
    own = counts[labels]                                           # [K]
    loo = (csum[labels] - P) / jnp.maximum(own - 1.0, 1.0)[:, None]
    p = jnp.clip(P, eps, None)
    q = jnp.clip(loo, eps, None)
    kld = jnp.sum(p * (jnp.log(p) - jnp.log(q)), axis=-1)
    return jnp.where(own > 1, kld, 0.0)


def federation_weights_jax(klds: jnp.ndarray, sizes: jnp.ndarray,
                           labels: jnp.ndarray, num_clusters: int,
                           beta: float = 150.0) -> jnp.ndarray:
    """Traced twin of federation_weights: within-cluster log-space
    softmax of ``log n_k − beta KLD_k`` via one-hot segment reductions
    (no host loop over cluster ids)."""
    onehot = jax.nn.one_hot(labels, num_clusters, dtype=jnp.float32)
    logits = (jnp.log(jnp.maximum(sizes.astype(jnp.float32), 1e-30))
              - beta * klds.astype(jnp.float32))
    seg_max = jnp.where(onehot > 0, logits[:, None], -jnp.inf).max(0)  # [C]
    e = jnp.exp(logits - seg_max[labels])
    denom = onehot.T @ e                                               # [C]
    return e / denom[labels]


def activation_weights_jax(acts: jnp.ndarray, sizes: jnp.ndarray,
                           labels: jnp.ndarray, num_clusters: int,
                           beta: float = 150.0
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """End-to-end Eq. 13-15 on device: returns (intra-cluster weights,
    klds) as device arrays. f32 (the numpy oracle runs f64 — agreement
    is to fp tolerance, amplified by beta in the weights)."""
    P = jax.nn.softmax(acts.astype(jnp.float32), axis=-1)
    klds = cluster_klds_jax(P, labels, num_clusters)
    return federation_weights_jax(klds, sizes, labels, num_clusters,
                                  beta), klds
