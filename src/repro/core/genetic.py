"""Genetic algorithm for optimal cut-point selection — paper §4.3 + App. D.

Minimizes `huscf_iteration_latency` over the joint per-client cut vector.
Implements the paper's exact operators:
  * tournament selection (size 5)
  * uniform crossover and two-point crossover, alternated 50/50,
    applied with probability `crossover_rate`
  * per-gene mutation with probability `mutation_rate`
  * elitism (top 2 carried over)
  * profile-based reduction (appendix D): one gene per *device profile*,
    upsampled to all clients for fitness evaluation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.latency import (Cut, DeviceProfile, PAPER_SERVER,
                                all_cut_options, huscf_iteration_latency)


@dataclasses.dataclass
class GAConfig:
    population_size: int = 1000
    generations: int = 60
    crossover_rate: float = 0.7
    mutation_rate: float = 0.01
    tournament_size: int = 5
    elitism: int = 2
    profile_based: bool = True
    seed: int = 0
    early_stop_patience: int = 15


@dataclasses.dataclass
class GAResult:
    cuts: List[Cut]            # per client
    latency: float
    generations_run: int
    convergence_gen: int       # first generation reaching the final best
    history: List[float]


def _fitness_factory(devices: Sequence[DeviceProfile],
                     server: DeviceProfile, batch: int,
                     profile_of: Optional[np.ndarray],
                     options: List[Cut]) -> Callable[[np.ndarray], float]:
    """individual: int array of option indices (per profile or per client)."""

    def fitness(ind: np.ndarray) -> float:
        if profile_of is not None:
            cuts = [options[ind[profile_of[k]]] for k in range(len(profile_of))]
        else:
            cuts = [options[g] for g in ind]
        return -huscf_iteration_latency(cuts, devices, server, batch)

    return fitness


def optimize_cuts(devices: Sequence[DeviceProfile],
                  server: DeviceProfile = PAPER_SERVER, *,
                  batch: int = 64, config: GAConfig = GAConfig()
                  ) -> GAResult:
    options = all_cut_options()
    n_opt = len(options)
    rng = np.random.default_rng(config.seed)

    if config.profile_based:
        # appendix D: collapse clients with identical profiles to one gene
        names = [d.name for d in devices]
        uniq = sorted(set(names))
        profile_idx = {nm: i for i, nm in enumerate(uniq)}
        profile_of = np.array([profile_idx[nm] for nm in names])
        n_genes = len(uniq)
    else:
        profile_of = None
        n_genes = len(devices)

    fitness = _fitness_factory(devices, server, batch, profile_of, options)

    pop = rng.integers(0, n_opt, size=(config.population_size, n_genes))
    fits = np.array([fitness(ind) for ind in pop])
    history: List[float] = []
    best_fit = -np.inf
    best_ind = pop[0].copy()
    convergence_gen = 0
    stall = 0
    gen = 0

    # memoize fitness: the gene space is small under profile reduction
    cache: dict = {}

    def cached_fitness(ind: np.ndarray) -> float:
        key = ind.tobytes()
        if key not in cache:
            cache[key] = fitness(ind)
        return cache[key]

    for gen in range(1, config.generations + 1):
        # --- selection + crossover + mutation -> next generation
        order = np.argsort(-fits)
        elite = pop[order[: config.elitism]].copy()
        children = []
        while len(children) < config.population_size - config.elitism:
            def tournament():
                idx = rng.integers(0, config.population_size,
                                   config.tournament_size)
                return pop[idx[np.argmax(fits[idx])]]

            p1, p2 = tournament().copy(), tournament().copy()
            if rng.random() < config.crossover_rate and n_genes > 1:
                if rng.random() < 0.5:  # uniform
                    mask = rng.random(n_genes) < 0.5
                    p1[mask], p2[mask] = p2[mask].copy(), p1[mask].copy()
                else:  # two-point
                    a, b_ = sorted(rng.integers(0, n_genes, 2))
                    p1[a:b_ + 1], p2[a:b_ + 1] = (p2[a:b_ + 1].copy(),
                                                  p1[a:b_ + 1].copy())
            for child in (p1, p2):
                mut = rng.random(n_genes) < config.mutation_rate
                child[mut] = rng.integers(0, n_opt, int(mut.sum()))
                children.append(child)
        pop = np.vstack([elite, np.array(children[: config.population_size
                                                  - config.elitism])])
        fits = np.array([cached_fitness(ind) for ind in pop])

        gen_best = float(fits.max())
        history.append(-gen_best)
        if gen_best > best_fit + 1e-12:
            best_fit = gen_best
            best_ind = pop[int(np.argmax(fits))].copy()
            convergence_gen = gen
            stall = 0
        else:
            stall += 1
            if stall >= config.early_stop_patience:
                break

    if profile_of is not None:
        cuts = [options[best_ind[profile_of[k]]] for k in range(len(devices))]
    else:
        cuts = [options[g] for g in best_ind]
    return GAResult(cuts=cuts, latency=-best_fit, generations_run=gen,
                    convergence_gen=convergence_gen, history=history)


def exhaustive_profile_optimum(devices: Sequence[DeviceProfile],
                               server: DeviceProfile = PAPER_SERVER,
                               batch: int = 64) -> Tuple[List[Cut], float]:
    """Brute-force per-profile *independent* greedy lower bound sanity
    check (not exact — barriers couple profiles — but a useful test
    reference for small populations)."""
    options = all_cut_options()
    names = [d.name for d in devices]
    uniq = sorted(set(names))
    best_global = None
    best_cuts = None
    # coordinate descent from a sensible start
    assign = {nm: options[0] for nm in uniq}
    for _ in range(4):
        for nm in uniq:
            best_local = None
            for opt in options:
                trial = dict(assign); trial[nm] = opt
                cuts = [trial[n_] for n_ in names]
                lat = huscf_iteration_latency(cuts, devices, server, batch)
                if best_local is None or lat < best_local[0]:
                    best_local = (lat, opt)
            assign[nm] = best_local[1]
            if best_global is None or best_local[0] < best_global:
                best_global = best_local[0]
                best_cuts = [assign[n_] for n_ in names]
    return best_cuts, best_global
