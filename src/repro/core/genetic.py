"""Genetic algorithm for optimal cut-point selection — paper §4.3 + App. D.

Minimizes `huscf_iteration_latency` over the joint per-client cut vector.
Implements the paper's exact operators:
  * tournament selection (size 5)
  * uniform crossover and two-point crossover, alternated 50/50,
    applied with probability `crossover_rate`
  * per-gene mutation with probability `mutation_rate`
  * elitism (top 2 carried over)
  * profile-based reduction (appendix D): one gene per *device profile*,
    upsampled to all clients for fitness evaluation.

Two execution paths:
  * ``GAConfig.fused=True`` (default): device-resident search. Fitness
    is the vectorized Eq. 3-10 model (``core.latency_jax``) over the
    whole ``[P, n_genes]`` population at once, and each generation
    (tournament gathers + argmax, 50/50 uniform/two-point crossover,
    per-gene mutation, ``top_k`` elitism) is one step of an in-graph
    ``lax.while_loop`` driven by a JAX PRNG key chain, with the
    early-stop patience as the loop exit. ``CutSearcher`` holds the
    staged tables + jitted program so *re*-optimization (churn,
    fluctuating bandwidth) costs one dispatch per round and runs under
    ``jax.transfer_guard("disallow_explicit")``.
  * ``GAConfig.fused=False``: the host numpy loop — one scalar fitness
    call per individual per generation — kept as the correctness /
    solution-quality oracle.

Bookkeeping convention (both paths): ``history[g]`` is generation g's
best latency with g=0 the *initial* population, so ``history`` has
``generations_run + 1`` entries; ``convergence_gen`` is the generation
whose population first contained the final best individual, and 0
means the initial population already did (the early-stop patience
counts generations since ``convergence_gen``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.latency import (Cut, DeviceProfile, PAPER_SERVER,
                                all_cut_options, huscf_iteration_latency)
from repro.core.latency_jax import (LatencyTables, build_latency_tables,
                                    population_latency)


@dataclasses.dataclass
class GAConfig:
    population_size: int = 1000
    generations: int = 60
    crossover_rate: float = 0.7
    mutation_rate: float = 0.01
    tournament_size: int = 5
    elitism: int = 2
    profile_based: bool = True
    seed: int = 0
    early_stop_patience: int = 15
    fused: bool = True           # device-resident GA; False = host numpy
    #                              oracle (identical operators, scalar
    #                              fitness per individual)


@dataclasses.dataclass
class GAResult:
    cuts: List[Cut]            # per client
    latency: float
    generations_run: int
    convergence_gen: int       # generation that first held the final best
    #                            (0 = already in the initial population)
    history: List[float]       # per-generation best, history[0] = gen 0


def _profile_reduction(devices: Sequence[DeviceProfile],
                       profile_based: bool
                       ) -> Tuple[Optional[np.ndarray], int]:
    """Appendix D: collapse clients with identical profiles to one gene.
    Returns (profile_of [K] or None, n_genes)."""
    if not profile_based:
        return None, len(devices)
    names = [d.name for d in devices]
    uniq = sorted(set(names))
    profile_idx = {nm: i for i, nm in enumerate(uniq)}
    return np.array([profile_idx[nm] for nm in names]), len(uniq)


def _upsample_cuts(ind: np.ndarray, profile_of: Optional[np.ndarray],
                   n_clients: int, options: List[Cut]) -> List[Cut]:
    if profile_of is not None:
        return [options[ind[profile_of[k]]] for k in range(n_clients)]
    return [options[g] for g in ind]


# ---------------------------------------------------------------------------
# fused device-resident search
# ---------------------------------------------------------------------------

class SearchOut(NamedTuple):
    """Device-array result of one fused GA run (read back at will)."""
    best_ind: jnp.ndarray        # [n_genes] int32 option indices
    best_latency: jnp.ndarray    # f32 scalar
    convergence_gen: jnp.ndarray  # int32
    generations_run: jnp.ndarray  # int32
    history: jnp.ndarray         # [generations+1] f32, nan-padded tail


@functools.lru_cache(maxsize=64)
def _get_search_fn(pop_size: int, n_genes: int, n_opt: int,
                   generations: int, crossover_rate: float,
                   mutation_rate: float, tournament_size: int,
                   elitism: int, patience: int,
                   with_counts: bool) -> Callable:
    """Jitted ``(key, LatencyTables[, counts]) -> SearchOut``, fully
    in-graph: one fused GA generation per while_loop step, the
    early-stop patience as the in-graph exit condition (like PR 4's
    Lloyd iteration). Cached on the static GA shape so every device
    population with the same (pop, genes) reuses one compiled program
    — tables/counts arrive as arguments, not baked constants."""
    n_elite = max(0, min(elitism, pop_size - 1))
    n_child = pop_size - n_elite
    n_pairs = (n_child + 1) // 2
    gene_idx = np.arange(n_genes)[None, :]

    def eval_pop(tables: LatencyTables, counts, pop: jnp.ndarray
                 ) -> jnp.ndarray:
        return -population_latency(tables, pop, counts)

    def generation(tables, counts, carry):
        key, pop, fits, best_ind, best_fit, conv, stall, gen, hist = carry
        keys = jax.random.split(key, 8)
        # elitism: top individuals carried over unmodified
        _, elite_rows = jax.lax.top_k(fits, n_elite)
        elite = pop[elite_rows]
        # tournament selection: random index gathers + argmax, two
        # independent parents per pair
        t_idx = jax.random.randint(keys[1], (2, n_pairs, tournament_size),
                                   0, pop_size)
        win = jnp.take_along_axis(
            t_idx, jnp.argmax(fits[t_idx], axis=-1)[..., None],
            axis=-1)[..., 0]
        p1, p2 = pop[win[0]], pop[win[1]]              # [n_pairs, G]
        # 50/50 uniform / two-point crossover, applied with
        # probability crossover_rate per pair (a gene-swap mask either
        # way, so both children come from one jnp.where pair)
        do_cross = jax.random.uniform(keys[2], (n_pairs, 1)) < crossover_rate
        use_uniform = jax.random.uniform(keys[3], (n_pairs, 1)) < 0.5
        umask = jax.random.uniform(keys[4], (n_pairs, n_genes)) < 0.5
        pts = jnp.sort(jax.random.randint(keys[5], (n_pairs, 2), 0, n_genes),
                       axis=1)
        tmask = (gene_idx >= pts[:, :1]) & (gene_idx <= pts[:, 1:])
        swap = do_cross & jnp.where(use_uniform, umask, tmask)
        children = jnp.concatenate([jnp.where(swap, p2, p1),
                                    jnp.where(swap, p1, p2)], 0)[:n_child]
        # per-gene mutation
        mmask = jax.random.uniform(keys[6], (n_child, n_genes)) < mutation_rate
        mvals = jax.random.randint(keys[7], (n_child, n_genes), 0, n_opt)
        children = jnp.where(mmask, mvals, children)
        pop = jnp.concatenate([elite, children], 0)
        fits = eval_pop(tables, counts, pop)
        gen = gen + 1
        gen_best = jnp.max(fits)
        improved = gen_best > best_fit + 1e-12
        best_fit = jnp.where(improved, gen_best, best_fit)
        best_ind = jnp.where(improved, pop[jnp.argmax(fits)], best_ind)
        conv = jnp.where(improved, gen, conv)
        stall = jnp.where(improved, jnp.int32(0), stall + 1)
        hist = hist.at[gen].set(-gen_best)
        return (keys[0], pop, fits, best_ind, best_fit, conv, stall, gen,
                hist)

    def search(key, tables: LatencyTables, counts=None) -> SearchOut:
        k_init, k_loop = jax.random.split(key)
        pop = jax.random.randint(k_init, (pop_size, n_genes), 0, n_opt,
                                 jnp.int32)
        fits = eval_pop(tables, counts, pop)
        best = jnp.argmax(fits)
        hist = jnp.full((generations + 1,), jnp.nan, jnp.float32)
        hist = hist.at[0].set(-fits[best])
        carry = (k_loop, pop, fits, pop[best], fits[best], jnp.int32(0),
                 jnp.int32(0), jnp.int32(0), hist)

        def cond(c):
            stall, gen = c[6], c[7]
            return (gen < generations) & (stall < patience)

        carry = jax.lax.while_loop(
            cond, functools.partial(generation, tables, counts), carry)
        _, _, _, best_ind, best_fit, conv, _, gen, hist = carry
        return SearchOut(best_ind, -best_fit, conv, gen, hist)

    if with_counts:
        return jax.jit(search)
    return jax.jit(lambda key, tables: search(key, tables, None))


class CutSearcher:
    """Staged, jitted GA cut search for one fixed device population.

    Build once (host-side table construction + trace), then ``run(key)``
    is a single dispatch with zero host<->device transfers — cheap
    enough to call every federation round. The trainer caches one
    searcher per (devices, server, batch, config) and rebuilds only on
    churn / profile change.
    """

    def __init__(self, devices: Sequence[DeviceProfile],
                 server: DeviceProfile = PAPER_SERVER, *,
                 batch: int = 64, config: GAConfig = None,
                 options: Optional[List[Cut]] = None):
        self.config = config = config or GAConfig()
        self.options = all_cut_options() if options is None else options
        self.n_clients = len(devices)
        profile_of, n_genes = _profile_reduction(devices,
                                                 config.profile_based)
        self.profile_of = profile_of
        self.n_genes = n_genes
        if profile_of is not None:
            # appendix D taken all the way: fitness itself collapses to
            # the unique profiles. Tables carry one row per profile and
            # a client-count vector — identical clients share a gene,
            # so their barrier/completion terms coincide (max is
            # idempotent) and only n_active needs the multiplicity.
            reps = [None] * n_genes
            for k, d in enumerate(devices):
                r = reps[profile_of[k]]
                if r is None:
                    reps[profile_of[k]] = d
                elif r != d:
                    # the collapsed evaluation would silently score a
                    # population that doesn't exist
                    raise ValueError(
                        f"devices sharing profile name {d.name!r} have "
                        f"different specs ({r} vs {d}); rename the "
                        "profile or set profile_based=False")
            self._counts = jnp.asarray(np.bincount(profile_of,
                                                   minlength=n_genes),
                                       jnp.float32)
            table_devices = reps
        else:
            self._counts = None
            table_devices = list(devices)
        self.tables = build_latency_tables(table_devices, server, batch,
                                           self.options)
        self._search = _get_search_fn(
            config.population_size, n_genes, len(self.options),
            config.generations, float(config.crossover_rate),
            float(config.mutation_rate), config.tournament_size,
            config.elitism, config.early_stop_patience,
            self._counts is not None)
        self._devices = list(devices)
        self._server = server
        self._batch = batch

    def run(self, key) -> SearchOut:
        """One full GA search from a device PRNG key. Device arrays in,
        device arrays out — safe under transfer_guard."""
        if self._counts is not None:
            return self._search(key, self.tables, self._counts)
        return self._search(key, self.tables)

    def to_result(self, out: SearchOut) -> GAResult:
        """Read back a SearchOut and re-evaluate the winning cuts
        through the host f64 model so the reported latency is exactly
        comparable with the numpy oracle's."""
        best_ind = np.asarray(out.best_ind)
        gens_run = int(out.generations_run)
        conv = int(out.convergence_gen)
        history = [float(h) for h in
                   np.asarray(out.history)[: gens_run + 1]]
        cuts = _upsample_cuts(best_ind, self.profile_of, self.n_clients,
                              self.options)
        latency = huscf_iteration_latency(cuts, self._devices,
                                          self._server, self._batch)
        # convention check: the converging generation's recorded best is
        # the final best (f32 tables vs host f64 -> loose tolerance)
        assert np.isclose(history[conv], float(out.best_latency),
                          rtol=1e-6), (history[conv], out.best_latency)
        return GAResult(cuts=cuts, latency=float(latency),
                        generations_run=gens_run, convergence_gen=conv,
                        history=history)


# ---------------------------------------------------------------------------
# host numpy oracle
# ---------------------------------------------------------------------------

def _fitness_factory(devices: Sequence[DeviceProfile],
                     server: DeviceProfile, batch: int,
                     profile_of: Optional[np.ndarray],
                     options: List[Cut]) -> Callable[[np.ndarray], float]:
    """individual: int array of option indices (per profile or per client)."""

    def fitness(ind: np.ndarray) -> float:
        cuts = _upsample_cuts(ind, profile_of, len(devices), options)
        return -huscf_iteration_latency(cuts, devices, server, batch)

    return fitness


def _optimize_cuts_host(devices: Sequence[DeviceProfile],
                        server: DeviceProfile, batch: int,
                        config: GAConfig) -> GAResult:
    options = all_cut_options()
    n_opt = len(options)
    rng = np.random.default_rng(config.seed)
    profile_of, n_genes = _profile_reduction(devices, config.profile_based)
    fitness = _fitness_factory(devices, server, batch, profile_of, options)

    pop = rng.integers(0, n_opt, size=(config.population_size, n_genes))
    fits = np.array([fitness(ind) for ind in pop])
    # generation 0: the initial population counts (history + best)
    best_fit = float(fits.max())
    best_ind = pop[int(np.argmax(fits))].copy()
    history: List[float] = [-best_fit]
    convergence_gen = 0
    stall = 0
    gen = 0

    # memoize fitness: the gene space is small under profile reduction
    cache: dict = {}

    def cached_fitness(ind: np.ndarray) -> float:
        key = ind.tobytes()
        if key not in cache:
            cache[key] = fitness(ind)
        return cache[key]

    for gen in range(1, config.generations + 1):
        # --- selection + crossover + mutation -> next generation
        order = np.argsort(-fits)
        elite = pop[order[: config.elitism]].copy()
        children = []
        while len(children) < config.population_size - config.elitism:
            def tournament():
                idx = rng.integers(0, config.population_size,
                                   config.tournament_size)
                return pop[idx[np.argmax(fits[idx])]]

            p1, p2 = tournament().copy(), tournament().copy()
            if rng.random() < config.crossover_rate and n_genes > 1:
                if rng.random() < 0.5:  # uniform
                    mask = rng.random(n_genes) < 0.5
                    p1[mask], p2[mask] = p2[mask].copy(), p1[mask].copy()
                else:  # two-point
                    a, b_ = sorted(rng.integers(0, n_genes, 2))
                    p1[a:b_ + 1], p2[a:b_ + 1] = (p2[a:b_ + 1].copy(),
                                                  p1[a:b_ + 1].copy())
            for child in (p1, p2):
                mut = rng.random(n_genes) < config.mutation_rate
                child[mut] = rng.integers(0, n_opt, int(mut.sum()))
                children.append(child)
        pop = np.vstack([elite, np.array(children[: config.population_size
                                                  - config.elitism])])
        fits = np.array([cached_fitness(ind) for ind in pop])

        gen_best = float(fits.max())
        history.append(-gen_best)
        if gen_best > best_fit + 1e-12:
            best_fit = gen_best
            best_ind = pop[int(np.argmax(fits))].copy()
            convergence_gen = gen
            stall = 0
        else:
            stall += 1
            if stall >= config.early_stop_patience:
                break

    # convention check: history[convergence_gen] is the final best
    assert history[convergence_gen] == -best_fit
    cuts = _upsample_cuts(best_ind, profile_of, len(devices), options)
    return GAResult(cuts=cuts, latency=-best_fit, generations_run=gen,
                    convergence_gen=convergence_gen, history=history)


def optimize_cuts(devices: Sequence[DeviceProfile],
                  server: DeviceProfile = PAPER_SERVER, *,
                  batch: int = 64, config: GAConfig = None,
                  fused: Optional[bool] = None) -> GAResult:
    """GA cut search. ``config.fused`` (overridable via the ``fused``
    kwarg) selects the device-resident path; the numpy oracle runs the
    same operators one scalar fitness call at a time."""
    config = config or GAConfig()
    if fused is not None:
        config = dataclasses.replace(config, fused=fused)
    if config.fused:
        searcher = CutSearcher(devices, server, batch=batch, config=config)
        out = searcher.run(jax.random.PRNGKey(config.seed))
        return searcher.to_result(out)
    return _optimize_cuts_host(devices, server, batch, config)


def exhaustive_profile_optimum(devices: Sequence[DeviceProfile],
                               server: DeviceProfile = PAPER_SERVER,
                               batch: int = 64) -> Tuple[List[Cut], float]:
    """Coordinate-descent-over-profiles sanity reference (not exact —
    barriers couple profiles — but a useful test bound for small
    populations).

    The full assignment is re-evaluated after every profile update and
    the (cuts, latency) snapshot is taken from that same evaluation, so
    the returned latency is by construction the latency *of the
    returned cuts* (the old mid-sweep snapshot could pair cuts with a
    latency measured for a different assignment)."""
    options = all_cut_options()
    names = [d.name for d in devices]
    uniq = sorted(set(names))
    best_global = None
    best_cuts = None
    # coordinate descent from a sensible start
    assign = {nm: options[0] for nm in uniq}
    for _ in range(4):
        for nm in uniq:
            best_local = None
            for opt in options:
                trial = dict(assign); trial[nm] = opt
                cuts = [trial[n_] for n_ in names]
                lat = huscf_iteration_latency(cuts, devices, server, batch)
                if best_local is None or lat < best_local[0]:
                    best_local = (lat, opt)
            assign[nm] = best_local[1]
            # re-evaluate the full updated assignment and snapshot cuts
            # + latency from the same evaluation
            cuts_now = [assign[n_] for n_ in names]
            lat_now = huscf_iteration_latency(cuts_now, devices, server,
                                              batch)
            if best_global is None or lat_now < best_global:
                best_global = lat_now
                best_cuts = cuts_now
    return best_cuts, best_global
