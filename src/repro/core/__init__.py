from repro.core.latency import (Cut, DeviceProfile, PAPER_DEVICES, PAPER_SERVER,
                                huscf_iteration_latency, fedgan_iteration_latency,
                                mdgan_iteration_latency, fedsplitgan_iteration_latency,
                                hflgan_iteration_latency, pflgan_iteration_latency)
from repro.core.genetic import GAConfig, GAResult, optimize_cuts
from repro.core.clustering import cluster_activations, kmeans, silhouette
from repro.core.kld import (activation_weights, label_weights, federation_weights,
                            global_weights, kl_divergence)
from repro.core.splitting import ProfileGroup, group_by_profile
from repro.core.federation import federate_client_params, fedavg_uniform, weighted_average_stacked
from repro.core.huscf import HuSCFConfig, HuSCFTrainer, build_net_apply
