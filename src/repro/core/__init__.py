from repro.core.latency import (Cut, DeviceProfile, PAPER_DEVICES, PAPER_SERVER,
                                huscf_iteration_latency, fedgan_iteration_latency,
                                mdgan_iteration_latency, fedsplitgan_iteration_latency,
                                hflgan_iteration_latency, pflgan_iteration_latency)
from repro.core.genetic import GAConfig, GAResult, optimize_cuts
from repro.core.clustering import (cluster_activations, cluster_activations_jax,
                                   canonicalize_labels, k_selection_bound,
                                   kmeans, kmeans_jax, silhouette,
                                   silhouette_jax)
from repro.core.kld import (activation_weights, activation_weights_jax,
                            label_weights, federation_weights,
                            federation_weights_jax, global_weights,
                            cohort_federation_weights,
                            cohort_federation_weights_jax,
                            kl_divergence)
from repro.core.registry import ClientRegistry
from repro.core.splitting import ProfileGroup, bucket_size, group_by_profile
from repro.core.segments import (SplitProgram, compile_split_program,
                                 join_barrier_scan, make_apply,
                                 program_forward_latency,
                                 program_iteration_latency,
                                 program_net_latency)
from repro.core.federation import (federate_client_params,
                                   federate_client_params_device,
                                   fedavg_uniform, weighted_average_stacked)
from repro.core.huscf import (HuSCFConfig, HuSCFTrainer, build_net_apply,
                              build_net_apply_legacy)
