"""HuSCF applied to single-network transformers (paper §7.3).

Two cuts per client (head / server-trunk / tail): the embedding plus the
first `cut_head` blocks and the last blocks plus the LM head stay on the
client (so raw tokens and predictions never leave it); the middle trunk
is shared on the server. Clients grouped by device profile exactly as in
the GAN trainer; client segments are stacked pytrees vmapped over the
population and sharded along the mesh data axis; the server trunk runs
under lax.scan with tensor parallelism.

This is the paper-technique dry-run subject for LM architectures: one
jitted `huscf_lm_train_step` with the same five-stage semantics (split
forward, autodiff backward, cluster+KLD federation over client copies).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import nn
from repro.models import transformer as T
from repro.optim import adam
from repro.sharding.policy import maybe_shard


@dataclasses.dataclass(frozen=True)
class LMProfileGroup:
    """Clients sharing a device profile: same (cut_head, cut_tail)."""
    name: str
    n_clients: int
    cut_head: int   # blocks on the client before the server trunk
    cut_tail: int   # blocks on the client after the server trunk


def default_groups(cfg: ArchConfig, n_weak: int = 2, n_strong: int = 2
                   ) -> List[LMProfileGroup]:
    """A representative heterogeneous population: weak devices hold one
    super-block head/tail, strong ones hold two."""
    pat = len(cfg.block_pattern)
    return [
        LMProfileGroup("weak", n_weak, pat, pat),
        LMProfileGroup("strong", n_strong, 2 * pat, 2 * pat),
    ]


def init_split_lm(key, cfg: ArchConfig, groups: Sequence[LMProfileGroup]
                  ) -> Dict[str, Any]:
    """Client stacks own embed + head/tail blocks + final norm; server
    owns the trunk (max span) shared by all."""
    pat = cfg.block_pattern
    n_pat = len(pat)
    max_head = max(g.cut_head for g in groups)
    max_tail = max(g.cut_tail for g in groups)
    trunk_layers = cfg.n_layers - max_head - max_tail
    n_super = trunk_layers // n_pat
    assert n_super >= 1, "trunk must keep at least one super-block"

    k_server, k_clients = jax.random.split(key)
    server = {"blocks": {
        f"p{j}_{kind}": jax.vmap(
            lambda kk: T.init_block(kk, cfg, kind))(
                jax.random.split(jax.random.fold_in(k_server, j), n_super))
        for j, kind in enumerate(pat)}}

    clients = {}
    for gi, g in enumerate(groups):
        kg = jax.random.fold_in(k_clients, gi)

        def one_client(kk):
            ks = jax.random.split(kk, 4)
            head = {f"h{i}_{pat[i % n_pat]}":
                    T.init_block(jax.random.fold_in(ks[0], i), cfg,
                                 pat[i % n_pat])
                    for i in range(g.cut_head)}
            tail = {f"t{i}_{pat[i % n_pat]}":
                    T.init_block(jax.random.fold_in(ks[1], i), cfg,
                                 pat[i % n_pat])
                    for i in range(g.cut_tail)}
            return {"embed": nn.embedding_init(ks[2], cfg.vocab, cfg.d_model,
                                               dtype=cfg.dtype),
                    "head": head, "tail": tail,
                    "final_norm": (nn.layernorm_init(cfg.d_model, cfg.dtype)
                                   if cfg.norm == "layernorm" else
                                   nn.rmsnorm_init(cfg.d_model, cfg.dtype))}

        clients[g.name] = jax.vmap(one_client)(
            jax.random.split(kg, g.n_clients))
    return {"server": server, "clients": clients}


def split_lm_forward(cfg: ArchConfig, params: Dict[str, Any],
                     groups: Sequence[LMProfileGroup],
                     tokens: Dict[str, jnp.ndarray], *, unroll: int = 1
                     ) -> Dict[str, jnp.ndarray]:
    """tokens: {group: [K_g, b, S]} -> logits {group: [K_g, b, S, V]}."""
    pat = cfg.block_pattern
    n_pat = len(pat)
    scale = jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    S = next(iter(tokens.values())).shape[-1]
    positions = jnp.arange(S)

    # --- client heads (vmapped over the stacked client axis)
    acts = {}
    for g in groups:
        def head_fn(cp, toks):
            x = nn.embedding_apply(cp["embed"], toks).astype(cfg.dtype) * scale
            for i in range(g.cut_head):
                kind = pat[i % n_pat]
                x, _ = T.block_seq(cfg, kind, cp["head"][f"h{i}_{kind}"], x,
                                   positions)
            return x
        acts[g.name] = jax.vmap(head_fn)(params["clients"][g.name],
                                         tokens[g.name])

    # --- server trunk over the concatenated population batch
    sizes = [acts[g.name].shape[0] * acts[g.name].shape[1] for g in groups]
    flat = [acts[g.name].reshape((-1, S, cfg.d_model)) for g in groups]
    x = jnp.concatenate(flat, 0) if len(flat) > 1 else flat[0]
    x = maybe_shard(x, "resid")

    def body(x, slice_p):
        for j, kind in enumerate(pat):
            x, _ = T.block_seq(cfg, kind, slice_p[f"p{j}_{kind}"], x,
                               positions)
        return x, None

    x, _ = lax.scan(lambda c, p: (jax.checkpoint(
        lambda cc, pp: body(cc, pp)[0])(c, p), None),
        x, params["server"]["blocks"], unroll=unroll)

    # --- client tails
    import numpy as _np
    parts = jnp.split(x, list(_np.cumsum(sizes)[:-1]), 0) \
        if len(sizes) > 1 else [x]
    out = {}
    for g, part in zip(groups, parts):
        part = part.reshape((g.n_clients, -1, S, cfg.d_model))

        def tail_fn(cp, x):
            for i in range(g.cut_tail):
                kind = pat[i % n_pat]
                x, _ = T.block_seq(cfg, kind, cp["tail"][f"t{i}_{kind}"], x,
                                   positions)
            x = (nn.layernorm_apply(cp["final_norm"], x)
                 if cfg.norm == "layernorm"
                 else nn.rmsnorm_apply(cp["final_norm"], x))
            return nn.embedding_attend(cp["embed"], x)
        out[g.name] = jax.vmap(tail_fn)(params["clients"][g.name], part)
    return out


def make_split_train_step(cfg: ArchConfig,
                          groups: Sequence[LMProfileGroup],
                          lr: float = 1e-4, unroll: int = 1):
    """Returns (train_step, opt_init) over the split-population state."""
    opt_init, opt_update = adam(lr, grad_clip=1.0)

    def loss_fn(params, batch):
        logits = split_lm_forward(cfg, params, groups, batch["tokens"],
                                  unroll=unroll)
        total, count = 0.0, 0
        for g in groups:
            lg = logits[g.name]
            logp = jax.nn.log_softmax(lg.astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(
                logp, batch["labels"][g.name][..., None], -1)[..., 0]
            total = total + nll.mean() * g.n_clients
            count += g.n_clients
        return total / count

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        opt_state, params = opt_update(opt_state, grads, params)
        return params, opt_state, {"loss": loss}

    return train_step, opt_init


def federate_split_lm(params: Dict[str, Any],
                      groups: Sequence[LMProfileGroup],
                      weights: "np.ndarray", labels: "np.ndarray"):
    """Clustered KLD-weighted federation of the client segments: the
    embedding + final norm (owned by every client) aggregate cluster-wise;
    head/tail blocks aggregate over the clients of the same profile in
    the same cluster (layer-wise ownership, as in the GAN trainer)."""
    import numpy as np
    new_clients = {}
    offset = 0
    offsets = {}
    for g in groups:
        offsets[g.name] = offset
        offset += g.n_clients
    # embedding/final_norm: owned by all -> cluster-wise global aggregation
    for g in groups:
        new_clients[g.name] = dict(params["clients"][g.name])
    for c in np.unique(labels):
        members = []  # (group, pos, weight)
        for g in groups:
            for pos in range(g.n_clients):
                cid = offsets[g.name] + pos
                if labels[cid] == c:
                    members.append((g, pos, weights[cid]))
        w = np.array([m[2] for m in members], np.float64)
        w = w / w.sum() if w.sum() > 0 else np.full(len(members),
                                                    1 / len(members))
        for key in ("embed", "final_norm"):
            copies = [jax.tree_util.tree_map(
                lambda x: x[pos], params["clients"][g.name][key])
                for g, pos, _ in members]
            stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                             *copies)
            agg = nn.tree_weighted_sum(stacked, jnp.asarray(w))
            for (g, pos, _) in members:
                new_clients[g.name][key] = jax.tree_util.tree_map(
                    lambda full, a: full.at[pos].set(a.astype(full.dtype)),
                    new_clients[g.name][key], agg)
        # head/tail blocks: aggregate within (profile, cluster)
        for g in groups:
            sel = [pos for gg, pos, _ in members if gg is g]
            if len(sel) < 2:
                continue
            wsel = np.array([weights[offsets[g.name] + p] for p in sel])
            wsel = wsel / wsel.sum()
            for key in ("head", "tail"):
                sub = jax.tree_util.tree_map(
                    lambda x: x[np.array(sel)], params["clients"][g.name][key])
                agg = nn.tree_weighted_sum(sub, jnp.asarray(wsel))
                new_clients[g.name][key] = jax.tree_util.tree_map(
                    lambda full, a: full.at[np.array(sel)].set(
                        jnp.broadcast_to(a, (len(sel),) + a.shape
                                         ).astype(full.dtype)),
                    new_clients[g.name][key], agg)
    return {"server": params["server"], "clients": new_clients}
