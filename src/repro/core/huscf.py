"""HuSCF-GAN trainer — the paper's five-stage procedure (§4.1).

1. GA cut selection from device capabilities (repro.core.genetic).
2. Heterogeneous U-shaped split learning for G and D (§4.4): client
   heads -> server trunk (per-layer concatenation across clients whose
   span covers the layer) -> client tails, for both networks, forward
   and backward (backward comes free via JAX autodiff through the same
   graph).
3. Every E epochs: K-means on mid-layer D activations (real data).
4. Intra-cluster KLD-weighted federation of client segments (Eq. 13-16),
   vanilla FedAvg for the first two rounds.
5. Evaluation hooks (generation for the metric suite).

Simulation semantics: clients grouped by profile (appendix D); each
group's client-side segments are stacked pytrees vmapped over clients.
On a TPU mesh the stacked client axis shards over ('pod','data') and
server segments over 'model' — see repro/launch/dryrun.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kld as kld_mod
from repro.core.clustering import (cluster_activations,
                                   cluster_activations_jax,
                                   k_selection_bound)
from repro.core.federation import (donate_default, federate_client_params,
                                   federate_client_params_device,
                                   fedavg_uniform)
from repro.core.genetic import CutSearcher, GAConfig, optimize_cuts
from repro.core.latency import Cut, DeviceProfile, PAPER_DEVICES, PAPER_SERVER, huscf_iteration_latency
from repro.core.registry import ClientRegistry
from repro.core.segments import (compile_split_program, make_apply,
                                 head_pass as _head_pass,
                                 tail_pass as _tail_pass)
from repro.core.splitting import (ProfileGroup, client_owned_layers,
                                  group_by_profile, layer_pair,
                                  server_union_span)
from repro.data.partition import ClientSpec
from repro.data.pipeline import sample_batch, stage_clients
from repro.sharding.policy import maybe_shard
from repro.models import gan
from repro.models.gan import (DISC_LAYER_DEFS, DISC_MIDDLE,
                              DISC_MIDDLE_FEATURES, GEN_LAYER_DEFS,
                              Z_DIM, d_loss_fn, g_loss_fn)
from repro.optim import adam

Array = jnp.ndarray

_EMA_DECAY = 0.8                     # middle-activation EMA (stage 3 input)

# client-ownable layer counts per net, derived from the model depth so
# a layer-defs change cannot silently mis-plan the federation buffer
_N_LAYERS = {"G": len(GEN_LAYER_DEFS), "D": len(DISC_LAYER_DEFS)}


@dataclasses.dataclass
class HuSCFConfig:
    batch: int = 32
    federate_every: int = 5          # E
    beta: float = 150.0              # KLD weight scale
    lr: float = 2e-4
    adam_b1: float = 0.5
    num_clusters: Optional[int] = None   # None -> silhouette selection
    seed: int = 0
    use_kernel: bool = False         # Pallas weighted_agg for aggregation
    steps_per_epoch: Optional[int] = None
    warmup_fed_rounds: int = 2       # vanilla FedAvg rounds (paper §4.5)
    fused_epoch: bool = True         # scan-fused device-resident epochs;
    #                                  False = per-step loop (oracle)
    fused_cluster: bool = True       # device-resident stage 3+4 (jitted
    #                                  k-means/silhouette/KLD + in-jit
    #                                  weight matrix); False = host
    #                                  numpy path (correctness oracle)
    epoch_unroll: Optional[int] = None
    # scan unroll for the fused epoch. None = backend auto: full unroll
    # on CPU (XLA:CPU only multithreads the entry computation, so a
    # while-loop body runs its convs single-threaded — measured ~2.3x
    # per-step wall on 2 cores), 1 (true scan, O(1) compile) on
    # TPU/GPU where the loop body parallelizes fine.
    cohort_size: Optional[int] = None
    # per-round participant count: each federate() samples this many of
    # the registered clients (core/registry.py) from a dedicated PRNG
    # chain; Eq. 15 weights renormalize over the cohort and everyone
    # else keeps their params. None = full participation (paper
    # default).
    agg_chunk: Optional[int] = None
    # chunk-streamed aggregation: the round scans client chunks of this
    # size instead of materializing the dense [K, D] buffer
    # (federation.FederationPlan.aggregate_chunked, O(chunk + clusters)
    # memory). None = dense fused round.
    reoptimize_every: Optional[int] = None
    # re-run the (fused, device-resident) GA cut search every this many
    # federation rounds against the *current* device profiles; when it
    # finds strictly better cuts the trainer regroups online (profile
    # groups, migrated client/server params, re-staged dataset) and
    # invalidates the FederationPlan cache. 1 = every round (cheap: one
    # cached-program dispatch per search). None = static cuts (paper).
    split_program: bool = True
    # True: forward/backward graphs execute the compiled SplitProgram
    # (core/segments.py) shared with the latency model and the serving
    # engine. False: the legacy hand-rolled per-group loops
    # (build_net_apply_legacy) — kept as the bit-exactness oracle
    # (tests/test_segments.py).


# ---------------------------------------------------------------------------
# functional forward passes over the split topology
# ---------------------------------------------------------------------------

# client-side segment passes (_head_pass/_tail_pass) now live in
# core.segments as head_pass/tail_pass, shared with the serving
# executor; imported above under their old names for the legacy oracle.


def build_net_apply(groups: Sequence[ProfileGroup], net: str,
                    capture_middle: bool = False,
                    concat_groups: bool = True):
    """Returns apply(client_params, server_params, inputs, train) ->
    (outputs {gname: [K,b,...]}, new_client, new_server, middles).

    inputs: {gname: tuple of per-client-stacked arrays fed to layer 0}.

    Compiles the cut configuration into a `core.segments.SplitProgram`
    and returns its executor — the same program structure the analytic
    latency model and the split-serving engine consume. Bit-exact with
    `build_net_apply_legacy` (the pre-SplitProgram loops, kept as the
    oracle behind ``HuSCFConfig.split_program=False``).
    """
    program = compile_split_program(groups, net)
    return make_apply(program, capture_middle=capture_middle,
                      concat_groups=concat_groups)


def build_net_apply_legacy(groups: Sequence[ProfileGroup], net: str,
                           capture_middle: bool = False,
                           concat_groups: bool = True):
    """Pre-SplitProgram implementation: hand-rolled per-group loops that
    re-derive layer activity from the cuts inline. Semantically (and
    bit-) identical to `build_net_apply`; survives as the equivalence
    oracle for tests and for ``HuSCFConfig.split_program=False``.

    concat_groups=True is the paper-faithful schedule (the server
    concatenates all clients' activations per layer, so BatchNorm stats
    span the whole population). False is the beyond-paper TPU
    optimization (EXPERIMENTS.md §Perf iteration 5): each profile group
    flows through the shared server weights separately, which keeps the
    client-sharded layout intact (no realignment all-gathers) at the
    cost of ghost-BatchNorm (per-group) statistics.
    """
    defs = GEN_LAYER_DEFS if net == "G" else DISC_LAYER_DEFS
    n = len(defs)
    middle = n // 2
    span = server_union_span(groups, net, n)

    def apply(client_params, server_params, inputs, train: bool):
        new_client = {g.name: dict(client_params[g.name]) for g in groups}
        new_server = dict(server_params)
        # --- heads (vmapped over clients)
        bufs: Dict[str, Array] = {}
        shapes: Dict[str, Tuple[int, int]] = {}
        for g in groups:
            h, _ = layer_pair(g.cut, net)
            head_fn = functools.partial(_head_pass, defs, stop=h, train=train)
            acts, upd = jax.vmap(lambda p, *xs: head_fn(p, xs))(
                client_params[g.name], *inputs[g.name])
            new_client[g.name].update(upd)
            k, b = acts.shape[0], acts.shape[1]
            shapes[g.name] = (k, b)
            bufs[g.name] = maybe_shard(
                acts.reshape((k * b,) + acts.shape[2:]), "rows")
        # --- server trunk with per-layer join/leave (paper Fig. 7)
        outs: Dict[str, Array] = {}
        middles: Dict[str, Array] = {}
        for l in span:
            active = [g for g in groups
                      if layer_pair(g.cut, net)[0] <= l < layer_pair(g.cut, net)[1]]
            if concat_groups:
                xs = [bufs[g.name] for g in active]
                sizes = [x.shape[0] for x in xs]
                x = jnp.concatenate(xs, 0) if len(xs) > 1 else xs[0]
                x, new_server[str(l)] = defs[l][1](server_params[str(l)], x,
                                                   train)
                parts = (jnp.split(x, list(np.cumsum(sizes)[:-1]), 0)
                         if len(xs) > 1 else [x])
            else:
                # per-group pass through the SAME shared server weights;
                # BN state updates merge by equal-weight averaging.
                parts, bn_updates = [], []
                for g in active:
                    y, upd = defs[l][1](server_params[str(l)],
                                        bufs[g.name], train)
                    parts.append(y)
                    bn_updates.append(upd)
                new_server[str(l)] = jax.tree_util.tree_map(
                    lambda *xs: sum(xs) / len(xs), *bn_updates)
            for g, part in zip(active, parts):
                bufs[g.name] = maybe_shard(part, "rows")
                if capture_middle and l == middle:
                    k, b = shapes[g.name]
                    mid = part.reshape((k, b) + part.shape[1:])
                    middles[g.name] = jnp.mean(
                        mid.reshape(k, b, -1).astype(jnp.float32), axis=1)
                if layer_pair(g.cut, net)[1] == l + 1:
                    outs[g.name] = bufs[g.name]
        # --- tails (vmapped)
        results: Dict[str, Array] = {}
        for g in groups:
            _, t = layer_pair(g.cut, net)
            k, b = shapes[g.name]
            x = outs[g.name]
            x = x.reshape((k, b) + x.shape[1:])
            tail_fn = functools.partial(_tail_pass, defs, start=t, n=n,
                                        train=train)
            y, upd = jax.vmap(tail_fn)(client_params[g.name], x)
            new_client[g.name].update(upd)
            results[g.name] = y
        return results, new_client, new_server, middles

    return apply


def make_epoch_fn(groups: Sequence[ProfileGroup], step_core: Callable,
                  sample: Callable, n_steps: int,
                  unroll: int = 1) -> Callable:
    """The scan-fused device-resident epoch (DESIGN.md §Device-resident
    epochs), shared by the trainer and the production-mesh dry-run so
    the lowered computation cannot drift from the one that trains.

    ``step_core(state, batch) -> (state, metrics, mids)`` with ``mids``
    the per-group ``[K_p, F]`` middle-activation batch means;
    ``sample(dataset, key) -> batch``. Returns
    ``epoch(state, dataset, key, ema, ema_init)`` scanning the carry
    ``(state, rng, mid_ema [K, F], ema_init)`` for ``n_steps``.
    """
    rows = {g.name: jnp.asarray(g.client_ids, jnp.int32) for g in groups}

    def epoch(state, dataset, key, ema, ema_init):
        def body(carry, _):
            state, key, ema, ema_init = carry
            key, ks = jax.random.split(key)
            state, metrics, mids = step_core(state, sample(dataset, ks))
            # middle-activation EMA lives in the carry as one [K, F]
            # array — no per-step device->host sync; it is read back
            # once per epoch for stage-3 clustering.
            for g in groups:
                m = mids[g.name].astype(jnp.float32)
                prev = ema[rows[g.name]]
                ema = ema.at[rows[g.name]].set(
                    jnp.where(ema_init,
                              _EMA_DECAY * prev + (1 - _EMA_DECAY) * m, m))
            return (state, key, ema, jnp.ones((), jnp.bool_)), metrics

        (state, key, ema, ema_init), metrics = jax.lax.scan(
            body, (state, key, ema, ema_init), None, length=n_steps,
            unroll=unroll)
        return state, key, ema, ema_init, metrics

    return epoch


# ---------------------------------------------------------------------------
# trainer
# ---------------------------------------------------------------------------

class HuSCFTrainer:
    """End-to-end HuSCF-GAN over a client population."""

    def __init__(self, clients: Sequence[ClientSpec],
                 devices: Optional[Sequence[DeviceProfile]] = None,
                 cuts: Optional[Sequence[Cut]] = None,
                 config: HuSCFConfig = HuSCFConfig(),
                 server: DeviceProfile = PAPER_SERVER,
                 ga_config: Optional[GAConfig] = None,
                 fed_mesh: Optional[Any] = None):
        # fed_mesh: jax Mesh for client-axis-sharded federation rounds
        # (launch.mesh.make_federation_mesh); None = single-device path.
        # A Mesh is a device-topology object, so it rides the trainer,
        # not the (value-semantics) HuSCFConfig dataclass.
        self.clients = list(clients)
        self.cfg = config
        self.fed_mesh = fed_mesh
        K = len(self.clients)
        if devices is None:
            devices = [PAPER_DEVICES[i % len(PAPER_DEVICES)] for i in range(K)]
        self.devices = list(devices)
        self.server_profile = server

        # Stage 1: GA cut selection
        self._ga_config = ga_config or GAConfig(population_size=200,
                                                generations=30,
                                                seed=config.seed)
        if cuts is None:
            result = optimize_cuts(self.devices, server, batch=config.batch,
                                   config=self._ga_config)
            cuts = result.cuts
            self.ga_latency = result.latency
        else:
            self.ga_latency = huscf_iteration_latency(cuts, self.devices,
                                                      server, config.batch)
        self.cuts = list(cuts)
        self.groups = group_by_profile(self.devices, self.cuts)
        self.sizes = np.array([c.n for c in self.clients], np.int64)

        key = jax.random.PRNGKey(config.seed)
        self.state = self._init_state(key)
        self._rng = np.random.default_rng(config.seed + 1)
        # device-resident data: every group's client rows staged once
        # (padded + valid counts); batches are drawn inside the jitted
        # step from the training PRNG key, so epochs never touch host
        # numpy. With a fed_mesh the rows shard over its client axes
        # and the rest of the training state replicates onto the same
        # device set (one mesh for step + federation).
        self._dataset = stage_clients(self.groups, self.clients,
                                      mesh=fed_mesh)
        self._train_key = jax.random.PRNGKey(config.seed + 1)
        self._mid_ema = jnp.zeros((K, DISC_MIDDLE_FEATURES), jnp.float32)
        self._ema_init = jnp.zeros((), jnp.bool_)
        # device-resident stage 3+4 inputs: dataset sizes staged once,
        # a dedicated cluster PRNG key split per round on device
        self._sizes_dev = jnp.asarray(self.sizes, jnp.float32)
        self._cluster_key = jax.random.PRNGKey(config.seed + 2)
        # population registry + per-round cohort sampling (its own key
        # chain so enabling cohorts never perturbs the cluster stream)
        self.registry = ClientRegistry.from_clients(self.clients)
        if config.cohort_size is not None and not (
                1 <= config.cohort_size <= K):
            raise ValueError(f"cohort_size {config.cohort_size} out of "
                             f"range for {K} registered clients")
        self._cohort_key = jax.random.PRNGKey(config.seed + 3)
        # on-device GA cut re-optimization: its own key chain + a
        # cache of staged searchers (rebuilt only when the device
        # population itself changes)
        self._ga_key = jax.random.PRNGKey(config.seed + 4)
        self._searchers: Dict = {}
        if fed_mesh is not None and fed_mesh.devices.size > 1:
            self.state = jax.tree_util.tree_map(self._put_replicated,
                                                self.state)
            self._train_key = self._put_replicated(self._train_key)
            self._mid_ema = self._put_replicated(self._mid_ema)
            self._ema_init = self._put_replicated(self._ema_init)
            self._sizes_dev = self._put_replicated(self._sizes_dev)
            self._cluster_key = self._put_replicated(self._cluster_key)
            self._cohort_key = self._put_replicated(self._cohort_key)
        # fused-federation plans (treedefs/leaf shapes/layer offsets),
        # built on first round and reused so repeat rounds pay zero
        # host-side tree walking.
        self._fed_plans: Dict = {}
        self._step_core = self._build_step_core()
        self._step_fn = self._build_step()
        self._epoch_fns: Dict[int, Callable] = {}
        self._cluster_fns: Dict[Tuple, Callable] = {}
        self._gen_fn = None
        self.fed_round = 0
        self.epoch = 0
        self._trained = False        # host mirror of _ema_init (no readback)
        self._mid_acc: Dict[int, np.ndarray] = {}
        self.history: List[Dict[str, float]] = []

    # -- initialization ----------------------------------------------------
    def _init_state(self, key) -> Dict[str, Any]:
        kg, kd, kc = jax.random.split(key, 3)
        n_g, n_d = len(GEN_LAYER_DEFS), len(DISC_LAYER_DEFS)
        # server holds the union span of every layer any client delegates
        server_g = {}
        for l in server_union_span(self.groups, "G", n_g):
            kg, sub = jax.random.split(kg)
            server_g[str(l)] = GEN_LAYER_DEFS[l][0](sub, jnp.float32)
        server_d = {}
        for l in server_union_span(self.groups, "D", n_d):
            kd, sub = jax.random.split(kd)
            server_d[str(l)] = DISC_LAYER_DEFS[l][0](sub, jnp.float32)

        client_g, client_d = {}, {}
        for g in self.groups:
            kc, k1, k2 = jax.random.split(kc, 3)
            gh, gt = g.cut.g_h, g.cut.g_t
            dh, dt = g.cut.d_h, g.cut.d_t
            keys_g = jax.random.split(k1, g.size)
            client_g[g.name] = {
                str(l): jax.vmap(lambda kk, l=l: GEN_LAYER_DEFS[l][0](kk, jnp.float32))(keys_g)
                for l in list(range(gh)) + list(range(gt, n_g))}
            keys_d = jax.random.split(k2, g.size)
            client_d[g.name] = {
                str(l): jax.vmap(lambda kk, l=l: DISC_LAYER_DEFS[l][0](kk, jnp.float32))(keys_d)
                for l in list(range(dh)) + list(range(dt, n_d))}

        g_params = {"client": client_g, "server": server_g}
        d_params = {"client": client_d, "server": server_d}
        # init fns kept: an online re-cut rebuilds the Adam moments for
        # the migrated param structure (the param->slot mapping changed)
        self._opt_init_g, self._opt_update_g = adam(self.cfg.lr,
                                                    b1=self.cfg.adam_b1)
        self._opt_init_d, self._opt_update_d = adam(self.cfg.lr,
                                                    b1=self.cfg.adam_b1)
        return {"G": g_params, "D": d_params,
                "opt_g": self._opt_init_g(g_params),
                "opt_d": self._opt_init_d(d_params),
                "step": jnp.zeros((), jnp.int32)}

    def _put_replicated(self, x):
        """Replicate a device value onto the federation mesh (identity
        without one)."""
        if self.fed_mesh is None or self.fed_mesh.devices.size <= 1:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.device_put(x, NamedSharding(self.fed_mesh, P()))

    # -- one training step (pure body, shared by both epoch paths) ---------
    def _build_step_core(self) -> Callable:
        build = (build_net_apply if self.cfg.split_program
                 else build_net_apply_legacy)
        gen_apply = build(self.groups, "G")
        disc_apply = build(self.groups, "D", capture_middle=True)
        groups = self.groups
        total_clients = sum(g.size for g in groups)
        opt_update_g, opt_update_d = self._opt_update_g, self._opt_update_d

        def mean_client_loss(logits: Dict[str, Array], target: float) -> Array:
            tot = 0.0
            for g in groups:
                per = gan.bce_logits(logits[g.name].reshape(-1), target)
                tot = tot + per * g.size
            return tot / total_clients

        def step(state, batch):
            g_params, d_params = state["G"], state["D"]

            # ---------------- discriminator update
            def d_loss(d_p):
                fake, _, _, _ = gen_apply(g_params["client"],
                                          g_params["server"],
                                          {g.name: (batch["z"][g.name],
                                                    batch["fake_y"][g.name])
                                           for g in groups}, True)
                fake = {k: jax.lax.stop_gradient(v) for k, v in fake.items()}
                lr_, ncr, nsr, mids = disc_apply(
                    d_p["client"], d_p["server"],
                    {g.name: (batch["real_img"][g.name],
                              batch["real_y"][g.name]) for g in groups}, True)
                lf_, _, _, _ = disc_apply(
                    d_p["client"], d_p["server"],
                    {g.name: (fake[g.name], batch["fake_y"][g.name])
                     for g in groups}, True)
                loss = (mean_client_loss(lr_, 1.0)
                        + mean_client_loss(lf_, 0.0))
                return loss, ({"client": ncr, "server": nsr}, mids)

            (loss_d, (d_bn, mids)), grads_d = jax.value_and_grad(
                d_loss, has_aux=True)(d_params)
            new_opt_d, d_new = opt_update_d(state["opt_d"], grads_d, d_params)
            # keep BatchNorm running stats from the real-data pass
            d_new = _merge_bn(d_new, d_bn)

            # ---------------- generator update (vs updated D)
            def g_loss(g_p):
                fake, ncg, nsg, _ = gen_apply(g_p["client"], g_p["server"],
                                              {g.name: (batch["z"][g.name],
                                                        batch["fake_y"][g.name])
                                               for g in groups}, True)
                logits, _, _, _ = disc_apply(
                    d_new["client"], d_new["server"],
                    {g.name: (fake[g.name], batch["fake_y"][g.name])
                     for g in groups}, True)
                loss = mean_client_loss(logits, 1.0)
                return loss, {"client": ncg, "server": nsg}

            (loss_g, g_bn), grads_g = jax.value_and_grad(
                g_loss, has_aux=True)(g_params)
            new_opt_g, g_new = opt_update_g(state["opt_g"], grads_g, g_params)
            g_new = _merge_bn(g_new, g_bn)

            new_state = {"G": g_new, "D": d_new, "opt_g": new_opt_g,
                         "opt_d": new_opt_d, "step": state["step"] + 1}
            metrics = {"loss_d": loss_d, "loss_g": loss_g}
            return new_state, metrics, mids

        return step

    # -- on-device batch sampling ------------------------------------------
    def _sample(self, dataset, key):
        """One batch drawn on device from the staged dataset — shared
        by the per-step oracle and the scan body so both paths consume
        the identical PRNG stream."""
        return sample_batch(dataset, key, batch=self.cfg.batch,
                            z_dim=Z_DIM, num_classes=gan.NUM_CLASSES)

    # -- per-step path (correctness oracle, fused_epoch=False) -------------
    def _build_step(self) -> Callable:
        core = self._step_core
        sample = self._sample

        def step(state, dataset, key):
            key, ks = jax.random.split(key)
            new_state, metrics, mids = core(state, sample(dataset, ks))
            return new_state, key, metrics, mids

        # the trainer replaces self.state right after every call, so the
        # old params/Adam buffers may alias into the update in place
        # (TPU/GPU; CPU XLA ignores donation).
        return jax.jit(step,
                       donate_argnums=(0,) if donate_default() else ())

    def _epoch_unroll(self, n_steps: int) -> int:
        if self.cfg.epoch_unroll is not None:
            return max(1, min(n_steps, self.cfg.epoch_unroll))
        return n_steps if jax.default_backend() == "cpu" else 1

    # -- scan-fused device-resident epoch (fused_epoch=True) ---------------
    def _build_epoch(self, n_steps: int) -> Callable:
        epoch = make_epoch_fn(self.groups, self._step_core, self._sample,
                              n_steps, unroll=self._epoch_unroll(n_steps))
        # donate the carry's state + EMA so Adam/param buffers update in
        # place across the whole epoch (the dataset argument is
        # read-only and must not be donated)
        return jax.jit(epoch,
                       donate_argnums=(0, 3) if donate_default() else ())

    # -- public API ----------------------------------------------------------
    def train_steps(self, n_steps: int) -> Dict[str, float]:
        if self.cfg.fused_epoch:
            fn = self._epoch_fns.get(n_steps)
            if fn is None:
                fn = self._epoch_fns[n_steps] = self._build_epoch(n_steps)
            (self.state, self._train_key, self._mid_ema, self._ema_init,
             metrics) = fn(self.state, self._dataset, self._train_key,
                           self._mid_ema, self._ema_init)
            # only after the epoch dispatched: a failed first call must
            # leave the fused federate()'s empty-EMA guard armed
            self._trained = True
            return {k: float(v[-1]) for k, v in metrics.items()}
        # oracle: one dispatch per step, blocking mid-activation
        # readback + per-client Python EMA each step
        last = {}
        for _ in range(n_steps):
            self.state, self._train_key, metrics, mids = self._step_fn(
                self.state, self._dataset, self._train_key)
            for g in self.groups:
                m = np.asarray(mids[g.name])
                for pos, cid in enumerate(g.client_ids):
                    prev = self._mid_acc.get(cid)
                    self._mid_acc[cid] = (
                        m[pos] if prev is None
                        else _EMA_DECAY * prev + (1 - _EMA_DECAY) * m[pos])
            last = {k: float(v) for k, v in metrics.items()}
            self._trained = True
        return last

    def train_epoch(self) -> Dict[str, float]:
        steps = self.cfg.steps_per_epoch or max(
            1, int(np.median(self.sizes)) // self.cfg.batch)
        metrics = self.train_steps(steps)
        self.epoch += 1
        if self.epoch % self.cfg.federate_every == 0:
            self.federate()
        self.history.append(metrics)
        return metrics

    def middle_activations(self) -> np.ndarray:
        if self.cfg.fused_epoch:
            if not bool(self._ema_init):
                # fail as loudly as the oracle path's empty-dict lookup
                # would — an all-zero EMA would cluster degenerately
                raise RuntimeError(
                    "middle_activations() before any training step: "
                    "the fused-epoch EMA is empty")
            # the EMA lives on device in the scan carry; this is the
            # one device->host readback per epoch (stage-3 clustering)
            return np.asarray(self._mid_ema)
        K = len(self.clients)
        feat = next(iter(self._mid_acc.values()))
        out = np.zeros((K,) + feat.shape, np.float32)
        for cid, v in self._mid_acc.items():
            out[cid] = v
        return out

    _MESH_DEFAULT = object()     # sentinel: mesh=None must stay sayable

    def federate(self, use_label_kld: bool = False,
                 mesh: Any = _MESH_DEFAULT) -> Dict[str, Any]:
        """Stages 3+4. Returns diagnostics.

        With ``cfg.fused_cluster`` (the default) the clustered rounds
        run entirely on device (jitted k-means + silhouette selection
        + log-space Eq. 13-15 + in-jit weight matrix) and the
        diagnostic arrays come back as device arrays. The host numpy
        path is the correctness oracle (``fused_cluster=False``) and
        still serves ``use_label_kld=True``, whose label histograms
        live on the host by construction.

        mesh overrides the trainer's ``fed_mesh`` for this round
        (client-axis-sharded aggregation); pass ``mesh=None``
        explicitly to force the single-device path on a trainer that
        has a ``fed_mesh``. Omitted = trainer default.

        With ``cfg.cohort_size`` each round first samples its cohort
        from the registry (dedicated PRNG chain, on device); Eq. 15
        weights renormalize over the cohort and non-members keep their
        params. ``cfg.agg_chunk`` streams the aggregation in client
        chunks instead of the dense [K, D] buffer."""
        mesh = self.fed_mesh if mesh is self._MESH_DEFAULT else mesh
        self.fed_round += 1
        recut = None
        if (self.cfg.reoptimize_every is not None
                and self.fed_round % self.cfg.reoptimize_every == 0):
            # online cut re-optimization: one cached-program GA
            # dispatch against the current profiles; regroups (and
            # invalidates the plan cache) only on strictly better cuts
            recut = self.reoptimize_cuts()
        cohort_ids = cohort_mask = None
        if self.cfg.cohort_size is not None:
            self._cohort_key, sub = jax.random.split(self._cohort_key)
            cohort_ids = self.registry.sample_cohort(sub,
                                                     self.cfg.cohort_size)
            cohort_mask = self.registry.cohort_mask(cohort_ids)
        if self.fed_round <= self.cfg.warmup_fed_rounds:
            # host fedavg path: the tiny cohort mask is the one
            # readback (warmup rounds predate the device-resident chain
            # anyway — cohort-critical runs set warmup_fed_rounds=0)
            mask_np = (None if cohort_mask is None
                       else np.asarray(cohort_mask))
            for net in ("G", "D"):
                wrapped = {g.name: {net: self.state[net]["client"][g.name]}
                           for g in self.groups}
                # the trainer drops its references right below, so the
                # round may donate the old client buffers (TPU/GPU)
                out = fedavg_uniform(self.groups, wrapped, self.sizes,
                                     n_layers={net: _N_LAYERS[net]},
                                     use_kernel=self.cfg.use_kernel,
                                     plan_cache=self._fed_plans,
                                     donate=donate_default(), mesh=mesh,
                                     chunk_size=self.cfg.agg_chunk,
                                     cohort_mask=mask_np)
                self.state[net]["client"] = {g.name: out[g.name][net]
                                             for g in self.groups}
            diag = {"round": self.fed_round, "mode": "fedavg"}
            if cohort_ids is not None:
                diag["cohort"] = cohort_ids
            if recut is not None:
                diag["recut"] = recut
            return diag

        if self.cfg.fused_cluster and not use_label_kld:
            diag = self._federate_fused(mesh, cohort_ids, cohort_mask)
            if recut is not None:
                diag["recut"] = recut
            return diag

        acts = self.middle_activations()
        cl = cluster_activations(acts, k=self.cfg.num_clusters,
                                 seed=self.cfg.seed)
        if use_label_kld:
            hists = np.stack([np.bincount(c.labels, minlength=gan.NUM_CLASSES)
                              for c in self.clients])
            weights, klds = kld_mod.label_weights(hists, self.sizes,
                                                  cl.labels, self.cfg.beta)
        else:
            weights, klds = kld_mod.activation_weights(acts, self.sizes,
                                                       cl.labels, self.cfg.beta)
        mask_np = None if cohort_mask is None else np.asarray(cohort_mask)
        if mask_np is not None:
            # KLDs stay full-cluster; only the Eq.-15 normalization
            # restricts to the sampled participants.
            weights = kld_mod.cohort_federation_weights(
                klds, self.sizes, cl.labels, mask_np, self.cfg.beta)
        for net in ("G", "D"):
            wrapped = {g.name: {net: self.state[net]["client"][g.name]}
                       for g in self.groups}
            out = federate_client_params(self.groups, wrapped, weights,
                                         cl.labels,
                                         n_layers={net: _N_LAYERS[net]},
                                         use_kernel=self.cfg.use_kernel,
                                         plan_cache=self._fed_plans,
                                         donate=donate_default(), mesh=mesh,
                                         chunk_size=self.cfg.agg_chunk,
                                         cohort_mask=mask_np)
            self.state[net]["client"] = {g.name: out[g.name][net]
                                         for g in self.groups}
        diag = {"round": self.fed_round, "mode": "clustered",
                "k": cl.k, "silhouette": cl.silhouette,
                "labels": cl.labels, "weights": weights, "klds": klds}
        if cohort_ids is not None:
            diag["cohort"] = cohort_ids
        if recut is not None:
            diag["recut"] = recut
        return diag

    # -- device-resident stage 3+4 (fused_cluster) -------------------------
    def _get_cluster_fn(self, with_cohort: bool = False) -> Callable:
        """Jitted (acts, sizes, key[, cohort_mask]) -> (labels, k, sil,
        weights, klds) — stage 3+4 compute in one dispatch. Cached per
        (beta, num_clusters, use_kernel, with_cohort) because
        benchmarks mutate cfg fields between rounds."""
        key = (float(self.cfg.beta), self.cfg.num_clusters,
               self.cfg.use_kernel, with_cohort)
        fn = self._cluster_fns.get(key)
        if fn is None:
            beta, k_cfg = float(self.cfg.beta), self.cfg.num_clusters
            use_kernel = self.cfg.use_kernel

            def cluster_weight(acts, sizes, key, cohort_mask=None):
                labels, k_sel, sil = cluster_activations_jax(
                    acts, key, k=k_cfg, use_kernel=use_kernel)
                weights, klds = kld_mod.activation_weights_jax(
                    acts, sizes, labels,
                    k_selection_bound(acts.shape[0], k_cfg), beta,
                    cohort_mask=cohort_mask)
                return labels, k_sel, sil, weights, klds

            if with_cohort:
                fn = jax.jit(lambda a, s, k, m: cluster_weight(a, s, k, m))
            else:
                fn = jax.jit(lambda a, s, k: cluster_weight(a, s, k))
            self._cluster_fns[key] = fn
        return fn

    def _federate_fused(self, mesh, cohort_ids=None,
                        cohort_mask=None) -> Dict[str, Any]:
        """Clustered round without leaving the device: the EMA feeds
        the jitted cluster+weight chain, whose device labels/weights
        feed the in-jit weight-matrix aggregation — zero host<->device
        transfers of activations/labels/weights between train_steps
        and the aggregated params; a sampled cohort (mask + ids device
        arrays from the registry) stays on device too. Diagnostics are
        device arrays (reading them back is the caller's choice)."""
        if not self._trained:
            # same failure mode as the oracle path's empty-EMA check,
            # but off a host flag: no device readback in this method
            raise RuntimeError(
                "federate() before any training step: the middle-"
                "activation EMA is empty")
        acts = (self._mid_ema if self.cfg.fused_epoch
                else jnp.asarray(self.middle_activations()))
        self._cluster_key, sub = jax.random.split(self._cluster_key)
        if cohort_mask is not None:
            labels, k_sel, sil, weights, klds = self._get_cluster_fn(
                with_cohort=True)(acts, self._sizes_dev, sub, cohort_mask)
        else:
            labels, k_sel, sil, weights, klds = self._get_cluster_fn()(
                acts, self._sizes_dev, sub)
        bound = k_selection_bound(len(self.clients), self.cfg.num_clusters)
        for net in ("G", "D"):
            wrapped = {g.name: {net: self.state[net]["client"][g.name]}
                       for g in self.groups}
            out = federate_client_params_device(
                self.groups, wrapped, weights, labels, bound,
                n_layers={net: _N_LAYERS[net]},
                use_kernel=self.cfg.use_kernel,
                plan_cache=self._fed_plans,
                donate=donate_default(), mesh=mesh,
                chunk_size=self.cfg.agg_chunk,
                cohort_mask=cohort_mask,
                cohort_size=self.cfg.cohort_size)
            self.state[net]["client"] = {g.name: out[g.name][net]
                                         for g in self.groups}
        diag = {"round": self.fed_round, "mode": "clustered",
                "k": k_sel, "silhouette": sil, "labels": labels,
                "weights": weights, "klds": klds}
        if cohort_ids is not None:
            diag["cohort"] = cohort_ids
        return diag

    # -- online cut re-optimization + population churn ---------------------
    def _get_searcher(self, devices: Optional[Sequence[DeviceProfile]] = None
                      ) -> CutSearcher:
        """Staged fused-GA searcher for a device population (default:
        the current one). Cached so repeat re-optimizations against an
        unchanged population cost one dispatch, not a rebuild; the
        jitted program itself is shared across searchers with the same
        GA shape (genetic._get_search_fn's lru_cache)."""
        devices = self.devices if devices is None else list(devices)
        key = (tuple(devices), self.server_profile, self.cfg.batch,
               dataclasses.astuple(self._ga_config))
        s = self._searchers.get(key)
        if s is None:
            s = self._searchers[key] = CutSearcher(
                devices, self.server_profile, batch=self.cfg.batch,
                config=self._ga_config)
        return s

    def _run_search(self, searcher: CutSearcher):
        """One GA dispatch off the trainer's GA key chain. The guard
        *enforces* that the per-round search is transfer-free (key
        split, staged tables, in-graph generations — device arrays
        only); readbacks happen in to_result, outside, and only when a
        result is adopted or compared."""
        self._ga_key, sub = jax.random.split(self._ga_key)
        with jax.transfer_guard("disallow_explicit"):
            return searcher.run(sub)

    def reoptimize_cuts(self) -> bool:
        """Re-run the (fused, device-resident) GA against the current
        device population; when it finds strictly better cuts than the
        live assignment, regroup online (migrated params, re-staged
        dataset, invalidated FederationPlan cache). Returns whether the
        cuts changed. GA ties / losses against the incumbent must NOT
        churn the population, so a no-better search is a no-op."""
        searcher = self._get_searcher()
        result = searcher.to_result(self._run_search(searcher))
        current = huscf_iteration_latency(self.cuts, self.devices,
                                          self.server_profile,
                                          self.cfg.batch)
        if result.latency >= current * (1 - 1e-9):
            return False
        self.ga_latency = result.latency
        self._rebuild_population(self.clients, self.devices, result.cuts,
                                 old_of=list(range(len(self.clients))))
        return True

    def apply_churn(self, leave: Sequence[int] = (),
                    join: Sequence[Tuple[ClientSpec, DeviceProfile]] = ()
                    ) -> List[Cut]:
        """Registry churn: ``leave`` = global client ids exiting,
        ``join`` = (ClientSpec, DeviceProfile) pairs entering.
        Membership changed, so cuts are re-derived unconditionally
        (unlike ``reoptimize_cuts``'s better-only policy) and the
        population rebuilds: survivors keep their trained params/EMA
        rows under their new global ids, joiners start from the
        server's copies (population mean where the server has none).
        Returns the new per-client cut list."""
        join = list(join)
        _, old_of = self.registry.churn(leave,
                                        [spec.n for spec, _ in join])
        joiners = iter(join)
        new_clients, new_devices = [], []
        for o in old_of:
            if o >= 0:
                new_clients.append(self.clients[o])
                new_devices.append(self.devices[o])
            else:
                spec, dev = next(joiners)
                new_clients.append(spec)
                new_devices.append(dev)
        searcher = self._get_searcher(new_devices)
        result = searcher.to_result(self._run_search(searcher))
        self.ga_latency = result.latency
        self._rebuild_population(new_clients, new_devices, result.cuts,
                                 old_of)
        return list(self.cuts)

    def update_profile(self, cid: int, profile: DeviceProfile) -> List[Cut]:
        """A registered client reports new capabilities (measured
        bandwidth / frequency drift). Re-derives cuts for the updated
        population and regroups — identity-preserving churn, so the
        client keeps its dataset, params and EMA row."""
        if not 0 <= cid < len(self.clients):
            raise ValueError(f"unknown client id {cid}")
        new_devices = list(self.devices)
        new_devices[cid] = profile
        searcher = self._get_searcher(new_devices)
        result = searcher.to_result(self._run_search(searcher))
        self.ga_latency = result.latency
        self._rebuild_population(self.clients, new_devices, result.cuts,
                                 old_of=list(range(len(self.clients))))
        return list(self.cuts)

    def _migrate_client_params(self, net: str,
                               new_groups: Sequence[ProfileGroup],
                               old_of: Sequence[int]) -> Dict[str, Any]:
        """Client-side param migration for a re-cut/churn rebuild.
        ``old_of[new_cid]`` is the old global client id (-1 = joiner).

        Policy: a layer the client already owned keeps its trained
        copy; a layer it newly owns takes the server's trained copy
        (the server held it — that client delegated it until now);
        joiners take server copies too, falling back to the old
        population mean for layers the old server never held (such a
        layer was owned by *every* old client, so the mean exists)."""
        defs = GEN_LAYER_DEFS if net == "G" else DISC_LAYER_DEFS
        n = len(defs)
        old_server = self.state[net]["server"]
        old_client = self.state[net]["client"]
        old_owned = {g.name: set(client_owned_layers(layer_pair(g.cut, net),
                                                     n))
                     for g in self.groups}
        old_loc = {}
        for g in self.groups:
            for pos, cid in enumerate(g.client_ids):
                old_loc[cid] = (g.name, pos)
        mean_cache: Dict[int, Any] = {}

        def pop_mean(l: int):
            if l not in mean_cache:
                stacks = [old_client[g.name][str(l)] for g in self.groups
                          if l in old_owned[g.name]]
                mean_cache[l] = jax.tree_util.tree_map(
                    lambda *xs: jnp.concatenate(xs, 0).mean(0), *stacks)
            return mean_cache[l]

        def one_client(old_cid: int, l: int):
            if old_cid >= 0:
                gname, pos = old_loc[old_cid]
                if l in old_owned[gname]:
                    return jax.tree_util.tree_map(
                        lambda x: x[pos], old_client[gname][str(l)])
            if str(l) in old_server:
                return old_server[str(l)]
            return pop_mean(l)

        out = {}
        for g in new_groups:
            owned = client_owned_layers(layer_pair(g.cut, net), n)
            out[g.name] = {
                str(l): jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs, 0),
                    *[one_client(old_of[cid], l) for cid in g.client_ids])
                for l in owned}
        return out

    def _migrate_server_params(self, net: str,
                               new_groups: Sequence[ProfileGroup]
                               ) -> Dict[str, Any]:
        """Server span under the new cuts: layers the server already
        held keep their trained copies; a layer newly delegated to the
        server was owned by every old client that hosted it, so it
        starts from the mean of those trained client copies."""
        defs = GEN_LAYER_DEFS if net == "G" else DISC_LAYER_DEFS
        n = len(defs)
        old_server = self.state[net]["server"]
        old_client = self.state[net]["client"]
        new_server = {}
        for l in server_union_span(new_groups, net, n):
            if str(l) in old_server:
                new_server[str(l)] = old_server[str(l)]
                continue
            stacks = [old_client[g.name][str(l)] for g in self.groups
                      if l in set(client_owned_layers(
                          layer_pair(g.cut, net), n))]
            new_server[str(l)] = jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs, 0).mean(0), *stacks)
        return new_server

    def _rebuild_population(self, new_clients: Sequence[ClientSpec],
                            new_devices: Sequence[DeviceProfile],
                            new_cuts: Sequence[Cut],
                            old_of: Sequence[int]) -> None:
        """Swap in a new (clients, devices, cuts) population online:
        migrate params + optimizer + EMA, re-stage the dataset, rebuild
        the traced programs, and invalidate the FederationPlan cache
        (its keys embed the old group cuts/client ids)."""
        new_groups = group_by_profile(new_devices, new_cuts)
        K_new = len(new_clients)
        new_state: Dict[str, Any] = {}
        for net in ("G", "D"):
            new_state[net] = {
                "client": self._migrate_client_params(net, new_groups,
                                                      old_of),
                "server": self._migrate_server_params(net, new_groups)}
        # Adam moments restart for the migrated structure; the step
        # counter survives so schedules/beta-corrections don't rewind
        new_state["opt_g"] = self._opt_init_g(new_state["G"])
        new_state["opt_d"] = self._opt_init_d(new_state["D"])
        new_state["step"] = self.state["step"]
        # middle-activation EMA is global-client-indexed, so survivors
        # keep their rows under the new ids; joiners start from the
        # survivor mean (neutral for stage-3 clustering until their own
        # activations arrive)
        old_ema = np.asarray(self._mid_ema)
        new_ema = np.zeros((K_new, old_ema.shape[1]), np.float32)
        surv = [(i, o) for i, o in enumerate(old_of) if o >= 0]
        for i, o in surv:
            new_ema[i] = old_ema[o]
        if self._trained and surv and len(surv) < K_new:
            fill = old_ema[[o for _, o in surv]].mean(0)
            for i, o in enumerate(old_of):
                if o < 0:
                    new_ema[i] = fill
        self._mid_acc = {i: self._mid_acc[o] for i, o in enumerate(old_of)
                         if o >= 0 and o in self._mid_acc}

        self.clients = list(new_clients)
        self.devices = list(new_devices)
        self.cuts = list(new_cuts)
        self.groups = new_groups
        self.sizes = np.array([c.n for c in self.clients], np.int64)
        self.registry = ClientRegistry.from_clients(self.clients)
        if self.cfg.cohort_size is not None and not (
                1 <= self.cfg.cohort_size <= K_new):
            raise ValueError(
                f"cohort_size {self.cfg.cohort_size} out of range for "
                f"{K_new} registered clients after churn")
        self._dataset = stage_clients(self.groups, self.clients,
                                      mesh=self.fed_mesh)
        self.state = jax.tree_util.tree_map(self._put_replicated, new_state)
        self._mid_ema = self._put_replicated(jnp.asarray(new_ema))
        self._sizes_dev = self._put_replicated(
            jnp.asarray(self.sizes, jnp.float32))
        # every traced artifact keyed on the old grouping is stale
        self._fed_plans.clear()
        self._epoch_fns.clear()
        self._gen_fn = None
        self._step_core = self._build_step_core()
        self._step_fn = self._build_step()

    # -- generation for evaluation ------------------------------------------
    def generate(self, n_per_client_batch: int, labels: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """Generate len(labels) images by cycling clients. labels [N]."""
        if self._gen_fn is None:
            build = (build_net_apply if self.cfg.split_program
                     else build_net_apply_legacy)
            gen_apply = build(self.groups, "G")

            def gen(state, z, y):
                out, _, _, _ = gen_apply(state["G"]["client"],
                                         state["G"]["server"], {
                    g.name: (z[g.name], y[g.name]) for g in self.groups},
                    False)
                return out
            self._gen_fn = jax.jit(gen)
        labels = np.asarray(labels)
        n_total = len(labels)
        imgs_all, labels_all = [], []
        pos = 0
        while pos < n_total:
            # each group consumes the next contiguous label chunk (a
            # shared cursor, not a shared window — groups must not
            # recycle each other's labels); only the final partial
            # chunk pads, and the padding is sliced off below.
            z, y = {}, {}
            cursor = pos
            for g in self.groups:
                need = min(n_per_client_batch, max(1, (n_total - pos)
                                                   // max(1, g.size)))
                cnt = g.size * need
                chunk = labels[cursor:cursor + cnt]
                if chunk.shape[0] < cnt:
                    chunk = np.concatenate(
                        [chunk, np.zeros(cnt - chunk.shape[0],
                                         labels.dtype)])
                cursor += cnt
                z[g.name] = self._rng.normal(0, 1, (g.size, need, Z_DIM)
                                             ).astype(np.float32)
                y[g.name] = chunk.reshape(g.size, need).astype(np.int32)
            out = self._gen_fn(self.state, z, y)
            for g in self.groups:
                arr = np.asarray(out[g.name]).reshape(-1, 28, 28, 1)
                imgs_all.append(arr)
                labels_all.append(y[g.name].reshape(-1))
            pos = cursor
        imgs = np.concatenate(imgs_all)[:n_total]
        labs = np.concatenate(labels_all)[:n_total]
        return imgs, labs


def _merge_bn(updated_params, bn_params):
    """Take optimizer-updated learnables but BatchNorm running stats
    (keys 'mean'/'var') from the forward pass."""
    flat_u = jax.tree_util.tree_flatten_with_path(updated_params)[0]
    flat_b = {jax.tree_util.keystr(p): v for p, v in
              jax.tree_util.tree_flatten_with_path(bn_params)[0]}
    out = []
    for path, val in flat_u:
        ks = jax.tree_util.keystr(path)
        if ks.endswith("['mean']") or ks.endswith("['var']"):
            out.append(flat_b.get(ks, val))
        else:
            out.append(val)
    treedef = jax.tree_util.tree_structure(updated_params)
    return jax.tree_util.tree_unflatten(treedef, out)
