"""Vectorized analytic latency model — Eq. (3)-(10) as jnp, batched
over whole GA populations.

The host model (`repro.core.latency.huscf_iteration_latency`) walks
Python loops over clients x layers per evaluation; the GA calls it once
per individual per generation, which is why cut search was a one-shot
preprocessing pass. This module evaluates a ``[P, K]`` population of
per-client cut-option indices in one dispatch:

* Everything that depends only on (client, cut option) is precomputed
  on the host in float64 — head/tail compute (Eq. 3/4 via segment-FLOP
  prefix sums), up/downlink transmission (Eq. 5/6 from the cut layer's
  ``act_bytes``) — and staged as ``[K, O]`` float32 tables (O = 16 cut
  options per net at n=5). An evaluation is then pure gathers.
* The Eq. 7/8 cumulative server schedules are ``lax.scan`` recurrences
  ``S[i+1] = max(S[i] + srv[i] * n_active[i], barrier[i])`` over the
  static n=5 layer axis, with the per-layer client-join barriers
  computed as masked segment-maxes over the K clients.
* ``vmap`` batches the whole thing over the population axis.

Precision: tables are exact-f64 values rounded once to f32; the
remaining on-device arithmetic is a handful of adds/maxes, so the
result tracks the host model to ~1e-7 relative (tested at 1e-6 over
every cut option — tests/test_latency_jax.py).
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.latency import (Cut, DeviceProfile, PAPER_SERVER,
                                all_cut_options)
from repro.core.segments import join_barrier_scan
from repro.models.gan import DISC_LAYER_COSTS, GEN_LAYER_COSTS


class NetTables(NamedTuple):
    """Per-network static tensors for one device population.

    [K, O]: per-(client, option) latency terms (seconds, f32).
    [O]:    per-option server downlink terms + cut indices.
    [n]:    per-layer server compute (per participating client).
    """
    head_f: jnp.ndarray          # [K, O] Eq. 3 head forward
    head_b: jnp.ndarray          # [K, O] Eq. 4 head backward
    tail_f: jnp.ndarray          # [K, O]
    tail_b: jnp.ndarray          # [K, O]
    up_f: jnp.ndarray            # [K, O] Eq. 5 uplink at head cut
    up_b: jnp.ndarray            # [K, O] Eq. 5 uplink at tail cut (bwd)
    down_f: jnp.ndarray          # [O]    Eq. 6 server downlink (fwd)
    down_b: jnp.ndarray          # [O]
    srv_f: jnp.ndarray           # [n]    server per-layer fwd compute
    srv_b: jnp.ndarray           # [n]
    cut_h: jnp.ndarray           # [O] int32 head end layer
    cut_t: jnp.ndarray           # [O] int32 tail start layer


class LatencyTables(NamedTuple):
    gen: NetTables
    disc: NetTables


def _net_tables(costs, pairs, devices: Sequence[DeviceProfile],
                server: DeviceProfile, batch: int) -> NetTables:
    """Host-side f64 table build for one network (G or D)."""
    n = len(costs)
    b = float(batch)
    ff = np.concatenate([[0.0], np.cumsum([c.flops_fwd for c in costs])])
    fb = np.concatenate([[0.0], np.cumsum([c.flops_bwd for c in costs])])
    act = np.array([c.act_bytes for c in costs], np.float64)
    h = np.array([p[0] for p in pairs], np.int64)      # [O]
    t = np.array([p[1] for p in pairs], np.int64)
    flops_dev = np.array([d.flops_per_s for d in devices], np.float64)
    rate_dev = np.array([d.rate_bytes_per_s for d in devices], np.float64)

    head_flops_f = ff[h]                               # [O]
    head_flops_b = fb[h]
    tail_flops_f = ff[n] - ff[t]
    tail_flops_b = fb[n] - fb[t]
    f32 = lambda x: jnp.asarray(np.asarray(x), jnp.float32)
    return NetTables(
        head_f=f32(b * head_flops_f[None, :] / flops_dev[:, None]),
        head_b=f32(b * head_flops_b[None, :] / flops_dev[:, None]),
        tail_f=f32(b * tail_flops_f[None, :] / flops_dev[:, None]),
        tail_b=f32(b * tail_flops_b[None, :] / flops_dev[:, None]),
        up_f=f32(b * act[h - 1][None, :] / rate_dev[:, None]),
        up_b=f32(b * act[t - 1][None, :] / rate_dev[:, None]),
        down_f=f32(b * act[t - 1] / server.rate_bytes_per_s),
        down_b=f32(b * act[h - 1] / server.rate_bytes_per_s),
        srv_f=f32(b * np.array([c.flops_fwd for c in costs])
                  / server.flops_per_s),
        srv_b=f32(b * np.array([c.flops_bwd for c in costs])
                  / server.flops_per_s),
        cut_h=jnp.asarray(h, jnp.int32),
        cut_t=jnp.asarray(t, jnp.int32),
    )


def build_latency_tables(devices: Sequence[DeviceProfile],
                         server: DeviceProfile = PAPER_SERVER,
                         batch: int = 64,
                         options: Optional[List[Cut]] = None
                         ) -> LatencyTables:
    """Stage the per-population static tensors on device. ``options``
    must be the same list the caller indexes into (default
    ``all_cut_options()``); G and D tables share that option axis."""
    options = all_cut_options() if options is None else options
    g_pairs = [(c.g_h, c.g_t) for c in options]
    d_pairs = [(c.d_h, c.d_t) for c in options]
    return LatencyTables(
        gen=_net_tables(GEN_LAYER_COSTS, g_pairs, devices, server, batch),
        disc=_net_tables(DISC_LAYER_COSTS, d_pairs, devices, server, batch))


def _one_net_latency_jax(t: NetTables, idx: jnp.ndarray,
                         counts: Optional[jnp.ndarray] = None):
    """(L_f, L_b) for one network and one individual ``idx [K]`` of
    cut-option indices. Mirrors latency._one_net_latency exactly.

    ``counts[k]`` (optional, f32) says row k of the tables stands for
    that many identical clients (appendix D profile collapse): the
    Eq. 7/8 ``n_active`` terms weight by it, while the barrier /
    completion maxes are unchanged because identical clients contribute
    identical join terms. With counts of all-ones this is exactly the
    per-client model."""
    K = idx.shape[0]
    rows = jnp.arange(K)
    h = t.cut_h[idx]                         # [K]
    tt = t.cut_t[idx]
    head_f = t.head_f[rows, idx]
    head_b = t.head_b[rows, idx]
    tail_f = t.tail_f[rows, idx]
    tail_b = t.tail_b[rows, idx]
    up_f = t.up_f[rows, idx]
    up_b = t.up_b[rows, idx]
    down_f = t.down_f[idx]
    down_b = t.down_b[idx]

    n = t.srv_f.shape[0]
    li = jnp.arange(n)
    # [n, K] layer-participation mask: h[k] <= i < t[k]
    active = (h[None, :] <= li[:, None]) & (li[:, None] < tt[None, :])
    if counts is None:
        n_act = active.sum(axis=1).astype(jnp.float32)
    else:
        n_act = (active * counts[None, :]).sum(axis=1)
    # per-layer join barriers as masked segment-maxes over clients
    # (join terms are >= 0, so an empty segment's max-with-0 matches
    # the host model's "max(joins) if joins else 0.0")
    barr_f = jnp.max(jnp.where(h[None, :] == li[:, None],
                               (head_f + up_f)[None, :], 0.0), axis=1)
    barr_b = jnp.max(jnp.where(tt[None, :] == li[:, None] + 1,
                               (tail_b + up_b)[None, :], 0.0), axis=1)

    # Eq. 7: S_f[i+1] = max(S_f[i] + srv_f[i] * n_active[i], barrier[i])
    # — the shared SplitProgram recurrence (core.segments).
    s_f = join_barrier_scan(t.srv_f * n_act, barr_f)
    s_f = jnp.concatenate([jnp.zeros(1, jnp.float32), s_f])      # [n+1]
    l_f = jnp.max(s_f[tt] + down_f + tail_f)
    # Eq. 8: S_b[i] = max(S_b[i+1] + srv_b[i] * n_active[i], barrier[i]),
    # swept top layer down (reverse scan; ys stay in layer order)
    s_b = join_barrier_scan(t.srv_b * n_act, barr_b, reverse=True)
    s_b = jnp.concatenate([s_b, jnp.zeros(1, jnp.float32)])      # [n+1]
    l_b = jnp.max(s_b[h] + down_b + head_b)
    return l_f, l_b


def huscf_iteration_latency_jax(tables: LatencyTables, idx: jnp.ndarray,
                                counts: Optional[jnp.ndarray] = None
                                ) -> jnp.ndarray:
    """Eq. (10) for one individual: ``idx [K]`` int cut-option indices
    (positions into the ``options`` list the tables were built from)
    -> scalar f32 iteration latency."""
    gf, gb = _one_net_latency_jax(tables.gen, idx, counts)
    df, db = _one_net_latency_jax(tables.disc, idx, counts)
    return gf + gb + 3.0 * (df + db)


def population_latency(tables: LatencyTables, idx_pop: jnp.ndarray,
                       counts: Optional[jnp.ndarray] = None
                       ) -> jnp.ndarray:
    """``idx_pop [P, K]`` -> ``[P]`` latencies (one vmapped dispatch)."""
    return jax.vmap(
        lambda ind: huscf_iteration_latency_jax(tables, ind, counts)
    )(idx_pop)
