"""Clustered, KLD-weighted, layer-wise federated aggregation — Eq. (16).

Client-side segments are aggregated *within clusters*; because cuts are
heterogeneous, aggregation is **layer-wise over the layer's owners**:
for model layer l and cluster C, every client k in C that holds l
(in its head or tail) contributes its copy with weight
s_k / sum_{owners(l) in C} s_j, and all owners receive the aggregate.
Server-side segments are single shared copies trained on the combined
stream (see DESIGN.md §7 for the interpretation of the paper's global
Eq. 16 on shared parameters).

Fused round (DESIGN.md §Fused federation): a cached ``FederationPlan``
packs every profile group's stacked client segments into one
contiguous ``theta [K, D]`` f32 buffer per net (one row per client
copy, one column run per ownable layer, zero-filled where a cut does
not own the layer), builds the block-diagonal Eq.-15/16 weight matrix
on the host — one block per (layer, cluster), one row per receiving
client copy, factored exactly as ``W = B @ A`` with ``A [S, K]`` the
per-segment reduce rows and ``B`` the one-hot broadcast — and runs
flatten -> A @ theta -> broadcast-gather -> unflatten as a single
jitted computation, one Pallas ``clustered_agg`` call per net when
``use_kernel=True``. Treedefs, leaf shapes, and layer/row offsets are
cached on the plan so repeat rounds do zero host-side tree walking.
The original quadruple loop (net x layer x cluster x member) is kept
as the correctness oracle behind ``fused=False``.

Sharded round (DESIGN.md §Sharded federation): with ``mesh=`` given,
``theta``'s client (row) axis shards over the mesh's ('pod', 'data')
axes — the same "rows" placement as every population-batch tensor —
and the ``A @ theta`` cluster reduction runs as a ``shard_map``-ed
local partial-sum (the Pallas ``clustered_agg`` kernel on each
shard's row block) followed by a ``psum`` over the client axis, so
every host ends the collective holding the replicated ``[S, D]``
cluster means and ``_unflatten`` stays local. When the client count
is not divisible by the mesh (``sharding.policy.client_axes``'s
sanitize fallback) or the mesh has one device, the plan silently
uses the single-device path; ``mesh=None`` (the default) is that
path byte-for-byte.

Device-resident round (DESIGN.md §Device-resident clustering): with
stage 3+4 running on device (``clustering.cluster_activations_jax`` +
``kld.activation_weights_jax``), ``federate_client_params_device``
consumes the resulting *device* labels/weights arrays and assembles
the block-diagonal weight matrix in-jit
(``FederationPlan.device_weight_segments``): one segment row per
(layer, cluster-id < k bound), so the segment count is fixed by the
static ``k_selection_bound`` and never retraces as the selected k
moves round to round.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.splitting import ProfileGroup, client_owned_layers, layer_pair
from repro.sharding.policy import client_axes

# Segment-count padding: round the number of (layer, cluster) blocks up
# so A's leading dim takes few distinct values (bounds jit retraces as
# the silhouette-selected k changes round to round) and stays
# sublane-aligned for the kernel.
_SEGMENT_PAD = 8


def weighted_average_stacked(stacked: Any, weights: jnp.ndarray,
                             use_kernel: bool = False) -> Any:
    """Weighted sum over the leading client axis of every leaf.
    `weights` must already be normalized over that axis."""
    if use_kernel:
        from repro.kernels import ops as kops
        return jax.tree_util.tree_map(
            lambda x: kops.weighted_agg(x, weights), stacked)
    w = weights.astype(jnp.float32)
    return jax.tree_util.tree_map(
        lambda x: jnp.einsum("k,k...->...", w, x.astype(jnp.float32)
                             ).astype(x.dtype), stacked)


# ---------------------------------------------------------------------------
# fused single-dispatch federation round
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _LeafSpec:
    shape: Tuple[int, ...]      # per-client shape (no leading K axis)
    size: int
    dtype: Any


@dataclasses.dataclass(frozen=True)
class _SegmentEntry:
    """One (group, layer) tile of the flat buffer: the group's rows x
    the layer's column run."""
    layer: int
    gname: str
    row0: int
    row1: int
    col0: int
    width: int                  # flat per-copy param count of the layer
    sid0: int                   # slice into the per-copy segment-id vec
    sid1: int
    treedef: Any
    leaves: Tuple[_LeafSpec, ...]


class FederationPlan:
    """Host-side flattening/aggregation plan for one (net, topology).

    Flat layout: ``theta [K, D]`` — one row per client copy (groups in
    canonical order), one contiguous column run per client-ownable
    layer (zero-filled where a client's cut does not own the layer).
    The Eq.-16 round is then ``W @ theta`` with the block-diagonal
    per-(layer, cluster) weight matrix, factored exactly as
    ``W = B @ A``: ``A [S, K]`` holds one normalized reduce row per
    segment and the one-hot ``B`` broadcasts each segment's aggregate
    back to every receiving copy (a gather on the [S, D] output,
    restricted to that layer's columns — non-member columns of an
    ``A`` row are never read).

    Built once from a template of the client params; repeat rounds
    reuse the cached treedefs/shapes/offsets and the jitted aggregate
    functions (retraced only when the segment count changes).

    ``mesh``: client-axis sharding for the round. The ``[K, D]``
    buffer's rows shard over the mesh's ('pod', 'data') axes and the
    reduction becomes a shard_map partial-sum + psum; falls back to
    the single-device path when K is not divisible by the mesh (or
    the mesh is trivial). Plans are cached per mesh identity — see
    ``get_federation_plan``.
    """

    def __init__(self, groups: Sequence[ProfileGroup], net: str,
                 n_layers: int, template: Dict[str, Dict[str, Any]],
                 mesh: Optional[Mesh] = None):
        self.net = net
        self.n_layers = n_layers
        self.mesh = mesh
        # rows: one per client copy, groups in canonical order
        self._group_rows: Dict[str, Tuple[int, int]] = {}
        self.row_cids: List[int] = []
        row = 0
        for g in groups:
            self._group_rows[g.name] = (row, row + g.size)
            self.row_cids.extend(g.client_ids)
            row += g.size
        self.n_rows = row

        owned: Dict[str, List[int]] = {
            g.name: client_owned_layers(layer_pair(g.cut, net), n_layers)
            for g in groups}
        layers = sorted({l for ls in owned.values() for l in ls})

        # columns: contiguous run per client-ownable layer; leaf specs
        # must agree across groups (same layer definition).
        self._col_runs: Dict[int, Tuple[int, int]] = {}
        col = 0
        layer_specs: Dict[int, Tuple] = {}
        for l in layers:
            for g in groups:
                if l not in owned[g.name]:
                    continue
                leaves, treedef = jax.tree_util.tree_flatten(
                    template[g.name][str(l)])
                specs = tuple(_LeafSpec(
                    tuple(x.shape[1:]),
                    int(np.prod(x.shape[1:], dtype=np.int64)),
                    x.dtype) for x in leaves)
                if l not in layer_specs:
                    layer_specs[l] = (treedef, specs)
                elif layer_specs[l][1] != specs:
                    raise ValueError(
                        f"layer {l} leaf layout differs across groups "
                        f"(group {g.name})")
            width = sum(s.size for s in layer_specs[l][1])
            self._col_runs[l] = (col, width)
            col += width
        self.n_cols = col

        # entries: (group, layer) tiles + the per-copy segment-id slice
        self.entries: List[_SegmentEntry] = []
        sid = 0
        for g in groups:
            r0, r1 = self._group_rows[g.name]
            for l in owned[g.name]:
                c0, w = self._col_runs[l]
                treedef, specs = layer_specs[l]
                self.entries.append(_SegmentEntry(
                    l, g.name, r0, r1, c0, w, sid, sid + g.size,
                    treedef, specs))
                sid += g.size
        self.n_copies = sid          # receiving (layer, client copy) pairs

        # per-layer owner rows for the weight blocks
        self._layer_rows: List[Tuple[int, np.ndarray, np.ndarray]] = []
        cids_arr = np.asarray(self.row_cids, np.int64)
        for l in layers:
            rows = np.concatenate([
                np.arange(*self._group_rows[g.name]) for g in groups
                if l in owned[g.name]])
            self._layer_rows.append((l, rows, cids_arr[rows]))
        # static per-copy indices for the in-jit weight-matrix build
        # (device_weight_segments): seg_id(copy) = layer_pos * C + label
        layer_pos = {l: i for i, (l, _, _) in enumerate(self._layer_rows)}
        self._copy_layer_pos = np.zeros(max(self.n_copies, 1), np.int32)
        self._copy_cid = np.zeros(max(self.n_copies, 1), np.int32)
        for e in self.entries:
            self._copy_layer_pos[e.sid0:e.sid1] = layer_pos[e.layer]
            self._copy_cid[e.sid0:e.sid1] = cids_arr[e.row0:e.row1]
        self._owned = owned
        self._groups_order = [g.name for g in groups]
        self._agg_fns: Dict[Tuple[bool, bool], Callable] = {}
        # client-axis placement: the divisibility-aware sanitize drops
        # the axes (-> None -> single-device path) when K % mesh != 0
        # or the mesh axes multiply to 1.
        self._client_axes = (None if mesh is None or self.n_rows == 0
                             else client_axes(mesh, self.n_rows))

    # -- host-side weight matrix (Eq. 15/16 block diagonal) ----------------
    def weight_segments(self, weights: np.ndarray, cluster_labels: np.ndarray
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (A [S, K], seg_ids [n_copies]).

        ``A`` rows are the normalized per-(layer, cluster) reduce
        weights over that layer's owner rows (zero elsewhere);
        ``seg_ids`` maps every receiving (layer, client copy) pair —
        in ``entries`` order — to its segment row, i.e. the one-hot
        broadcast factor ``B`` of the block-diagonal ``W = B @ A``.
        S is padded to a multiple of _SEGMENT_PAD with zero rows
        (bounds retraces; padded segments are never gathered)."""
        rows_a: List[np.ndarray] = []
        seg_of: Dict[Tuple[int, int], int] = {}
        for l, rows, cids in self._layer_rows:
            for c in np.unique(cluster_labels[cids]):
                sel = cluster_labels[cids] == c
                w = np.asarray(weights, np.float64)[cids[sel]]
                if w.sum() <= 0:
                    w = np.ones_like(w)
                w = w / w.sum()
                a = np.zeros(self.n_rows, np.float32)
                a[rows[sel]] = w.astype(np.float32)
                seg_of[(l, int(c))] = len(rows_a)
                rows_a.append(a)
        seg_ids = np.zeros(self.n_copies, np.int32)
        for e in self.entries:
            row_cids = self.row_cids[e.row0:e.row1]
            seg_ids[e.sid0:e.sid1] = [
                seg_of[(e.layer, int(cluster_labels[cid]))]
                for cid in row_cids]
        S = max(_SEGMENT_PAD,
                -(-len(rows_a) // _SEGMENT_PAD) * _SEGMENT_PAD)
        A = np.zeros((S, self.n_rows), np.float32)
        if rows_a:
            A[:len(rows_a)] = np.stack(rows_a)
        return A, seg_ids

    # -- device-side weight matrix (traced twin, in-jit) -------------------
    def device_weight_segments(self, weights: jnp.ndarray,
                               labels: jnp.ndarray, num_clusters: int
                               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Traced twin of ``weight_segments``: assemble (A [S, K],
        seg_ids [n_copies]) from *device* per-client weights/labels so
        the whole round stays in one jit (DESIGN.md §Device-resident
        clustering).

        Unlike the host path, which enumerates only the clusters
        actually present, every (layer, cluster-id < num_clusters)
        pair gets a segment row — ``num_clusters`` is the static
        ``k_selection_bound``, so S is fixed and the round never
        retraces as the silhouette-selected k moves. Rows of empty
        segments are zero and never gathered (their seg_id is never
        produced); a present segment whose member weights sum to zero
        falls back to uniform over its members, like the host path."""
        C = int(num_clusters)
        n_seg = len(self._layer_rows) * C
        S = max(_SEGMENT_PAD, -(-n_seg // _SEGMENT_PAD) * _SEGMENT_PAD)
        A = jnp.zeros((S, self.n_rows), jnp.float32)
        w = weights.astype(jnp.float32)
        for li, (l, rows, cids) in enumerate(self._layer_rows):
            lab = labels[cids]                                     # [R]
            onehot = jax.nn.one_hot(lab, C, dtype=jnp.float32)     # [R, C]
            raw = onehot * w[cids][:, None]
            denom = raw.sum(0)                                     # [C]
            cnt = onehot.sum(0)
            blk = jnp.where(denom > 0,
                            raw / jnp.where(denom > 0, denom, 1.0),
                            onehot / jnp.maximum(cnt, 1.0))        # [R, C]
            A = A.at[li * C:(li + 1) * C, rows].set(blk.T)
        seg_ids = (jnp.asarray(self._copy_layer_pos[:self.n_copies]) * C
                   + labels[jnp.asarray(self._copy_cid[:self.n_copies])]
                   ).astype(jnp.int32)
        return A, seg_ids

    # -- device-side flatten / unflatten (inside jit) ----------------------
    def _flatten(self, net_params: Dict[str, Dict[str, Any]]) -> jnp.ndarray:
        bufs = []
        for gname in self._groups_order:
            r0, r1 = self._group_rows[gname]
            k = r1 - r0
            parts, col = [], 0
            for l, (c0, w) in sorted(self._col_runs.items()):
                assert c0 == col
                if l in self._owned[gname]:
                    leaves = jax.tree_util.tree_leaves(net_params[gname][str(l)])
                    parts.append(jnp.concatenate(
                        [x.reshape(k, -1).astype(jnp.float32)
                         for x in leaves], axis=1))
                else:
                    parts.append(jnp.zeros((k, w), jnp.float32))
                col += w
            bufs.append(jnp.concatenate(parts, axis=1))
        return jnp.concatenate(bufs, axis=0)

    def _unflatten(self, agg: jnp.ndarray, seg_ids: jnp.ndarray
                   ) -> Dict[str, Dict[str, Any]]:
        out: Dict[str, Dict[str, Any]] = {}
        for e in self.entries:
            block = jnp.take(agg[:, e.col0:e.col0 + e.width],
                             seg_ids[e.sid0:e.sid1], axis=0)
            leaves, off = [], 0
            for s in e.leaves:
                leaves.append(block[:, off:off + s.size]
                              .reshape((e.row1 - e.row0,) + s.shape)
                              .astype(s.dtype))
                off += s.size
            out.setdefault(e.gname, {})[str(e.layer)] = \
                jax.tree_util.tree_unflatten(e.treedef, leaves)
        return out

    # -- the jitted round --------------------------------------------------
    def _reduce_fn(self, use_kernel: bool) -> Callable:
        """(A [S, K], theta [K, D]) -> replicated agg [S, D] f32."""
        if self._client_axes is None:
            # single-device / fallback path: one full-K contraction.
            def reduce(A, theta):
                if use_kernel:
                    from repro.kernels import ops as kops
                    return kops.clustered_agg(A, theta)
                return A @ theta
            return reduce

        # Sharded path: theta rows and A columns split over the client
        # axis; each shard contracts its local row block (the Pallas
        # kernel runs per-shard) into a partial [S, D], and one psum
        # over the client axis leaves the full cluster means replicated
        # on every host — S*D is tiny next to K*D, and _unflatten's
        # seg_ids gather needs every segment row locally, so a
        # psum_scatter would only defer the same all-gather (DESIGN.md
        # §Sharded federation).
        axes = self._client_axes
        axis_names = (axes,) if isinstance(axes, str) else tuple(axes)

        def local_partial(a_blk, theta_blk):
            if use_kernel:
                from repro.kernels import ops as kops
                part = kops.clustered_agg(a_blk, theta_blk)
            else:
                part = a_blk @ theta_blk
            return jax.lax.psum(part.astype(jnp.float32), axis_names)

        # check_rep=False: pallas_call has no shard_map replication
        # rule; the out_spec below is still fully replicated (psum).
        return shard_map(local_partial, mesh=self.mesh,
                         in_specs=(P(None, axes), P(axes, None)),
                         out_specs=P(None, None), check_rep=False)

    def _make_agg_fn(self, use_kernel: bool, donate: bool) -> Callable:
        reduce = self._reduce_fn(use_kernel)
        theta_sharding = (None if self._client_axes is None else
                          NamedSharding(self.mesh, P(self._client_axes, None)))

        def fn(net_params, A, seg_ids):
            theta = self._flatten(net_params)
            if theta_sharding is not None:
                theta = jax.lax.with_sharding_constraint(theta, theta_sharding)
            agg = reduce(A, theta)
            return self._unflatten(agg, seg_ids)
        return jax.jit(fn, donate_argnums=(0,) if donate else ())

    def aggregate(self, net_params: Dict[str, Dict[str, Any]],
                  A: np.ndarray, seg_ids: np.ndarray,
                  use_kernel: bool = False,
                  donate: bool = False) -> Dict[str, Dict[str, Any]]:
        key = (use_kernel, donate)
        if key not in self._agg_fns:
            self._agg_fns[key] = self._make_agg_fn(use_kernel, donate)
        return self._agg_fns[key](net_params, jnp.asarray(A, jnp.float32),
                                  jnp.asarray(seg_ids, jnp.int32))

    def _make_agg_device_fn(self, num_clusters: int, use_kernel: bool,
                            donate: bool) -> Callable:
        reduce = self._reduce_fn(use_kernel)
        theta_sharding = (None if self._client_axes is None else
                          NamedSharding(self.mesh, P(self._client_axes, None)))

        def fn(net_params, weights, labels):
            A, seg_ids = self.device_weight_segments(weights, labels,
                                                     num_clusters)
            theta = self._flatten(net_params)
            if theta_sharding is not None:
                theta = jax.lax.with_sharding_constraint(theta, theta_sharding)
            agg = reduce(A, theta)
            return self._unflatten(agg, seg_ids)
        return jax.jit(fn, donate_argnums=(0,) if donate else ())

    def aggregate_device(self, net_params: Dict[str, Dict[str, Any]],
                         weights: jnp.ndarray, labels: jnp.ndarray,
                         num_clusters: int, use_kernel: bool = False,
                         donate: bool = False) -> Dict[str, Dict[str, Any]]:
        """Device-resident round: weights/labels are per-client device
        arrays (label ids < the static ``num_clusters`` bound); the
        Eq.-15/16 weight matrix is assembled in-jit — no host numpy
        between the inputs and the aggregated params. weights/labels
        are never donated (the caller reuses them across nets)."""
        key = ("device", int(num_clusters), use_kernel, donate)
        if key not in self._agg_fns:
            self._agg_fns[key] = self._make_agg_device_fn(
                int(num_clusters), use_kernel, donate)
        return self._agg_fns[key](net_params, weights, labels)


_PLAN_CACHE: Dict[Tuple, FederationPlan] = {}


def _plan_key(groups: Sequence[ProfileGroup], net: str, n_layers: int,
              template: Dict[str, Dict[str, Any]],
              mesh: Optional[Mesh] = None) -> Tuple:
    # The leaf-layout fingerprint guards the shared cache against two
    # same-topology populations with differently-shaped layer params
    # (walking ~100 aval objects per round is noise next to the round).
    # Mesh identity is part of the key: a plan bakes its shard_map /
    # sharding constraints to one mesh, so the same topology on a
    # different mesh (or none) must get its own plan (jax.sharding.Mesh
    # hashes by device assignment + axis names).
    layout = tuple(
        (g.name, tuple(
            (l, tuple((tuple(x.shape), str(x.dtype)) for x in
                      jax.tree_util.tree_leaves(tree)))
            for l, tree in sorted(template[g.name].items())))
        for g in groups)
    return (net, n_layers, tuple(
        (g.name, g.cut.as_tuple(), tuple(g.client_ids)) for g in groups),
        layout, mesh)


def get_federation_plan(groups: Sequence[ProfileGroup], net: str,
                        n_layers: int,
                        template: Dict[str, Dict[str, Any]],
                        plan_cache: Optional[Dict] = None,
                        mesh: Optional[Mesh] = None) -> FederationPlan:
    cache = _PLAN_CACHE if plan_cache is None else plan_cache
    key = _plan_key(groups, net, n_layers, template, mesh)
    if key not in cache:
        cache[key] = FederationPlan(groups, net, n_layers, template,
                                    mesh=mesh)
    return cache[key]


def _default_n_layers() -> Dict[str, int]:
    """Per-net layer counts derived from the model depth (lazy import:
    federation must stay importable without the models package in the
    graph at module load). A hardcoded {net: 5} here would silently
    mis-plan the flat buffer if the layer defs ever grow."""
    from repro.models.gan import DISC_LAYER_DEFS, GEN_LAYER_DEFS
    return {"G": len(GEN_LAYER_DEFS), "D": len(DISC_LAYER_DEFS)}


def donate_default() -> bool:
    """Whether a caller that *owns* its buffers (replaces every
    reference after the round, like the trainer) should donate them.
    CPU XLA ignores donation (with a warning per call) — only donate
    where the runtime can actually alias the buffers."""
    return jax.default_backend() in ("tpu", "gpu")


def federate_client_params(groups: Sequence[ProfileGroup],
                           client_params: Dict[str, Dict[str, Dict[str, Any]]],
                           weights: np.ndarray,
                           cluster_labels: np.ndarray,
                           n_layers: Dict[str, int] = None,
                           use_kernel: bool = False,
                           fused: bool = True,
                           plan_cache: Optional[Dict] = None,
                           donate: Optional[bool] = None,
                           mesh: Optional[Mesh] = None
                           ) -> Dict[str, Dict[str, Dict[str, Any]]]:
    """Aggregate client-held layers cluster-wise.

    client_params: {group.name: {net: {str(layer): stacked pytree}}}
    weights: Eq.-15 intra-cluster weights, indexed by global client id.
    cluster_labels: cluster id per global client id.
    fused=True runs the single-dispatch flat-buffer path (one jitted
    call per net; Pallas kernel when use_kernel); fused=False runs the
    legacy per-(layer, cluster, leaf) loop (correctness oracle).
    donate=True aliases the input buffers into the jitted round —
    only safe when the caller drops every reference to client_params
    afterwards (the trainer does; pass ``donate_default()``). The
    default never donates, so repeated calls on the same params are
    always valid.
    mesh=Mesh(...) shards the flat client buffer's rows over the
    mesh's ('pod', 'data') axes and reduces via shard_map partial-sums
    + psum (see FederationPlan); ``None`` keeps today's single-device
    path unchanged. Non-divisible client counts fall back silently.
    Returns a new client_params with aggregated copies broadcast back.
    """
    n_layers = n_layers or _default_n_layers()
    if not fused:
        return _federate_client_params_legacy(
            groups, client_params, weights, cluster_labels,
            n_layers=n_layers, use_kernel=use_kernel)
    if donate is None:
        donate = False
    weights = np.asarray(weights)
    cluster_labels = np.asarray(cluster_labels)
    out = {gname: dict(nets) for gname, nets in client_params.items()}
    for net, n_lay in n_layers.items():
        template = {g.name: client_params[g.name][net] for g in groups}
        plan = get_federation_plan(groups, net, n_lay, template,
                                   plan_cache=plan_cache, mesh=mesh)
        if plan.n_rows == 0:
            continue
        A, seg_ids = plan.weight_segments(weights, cluster_labels)
        new_net = plan.aggregate(template, A, seg_ids,
                                 use_kernel=use_kernel, donate=donate)
        for g in groups:
            if g.name in new_net:
                out[g.name][net] = new_net[g.name]
    return out


def federate_client_params_device(
        groups: Sequence[ProfileGroup],
        client_params: Dict[str, Dict[str, Dict[str, Any]]],
        weights: jnp.ndarray,
        cluster_labels: jnp.ndarray,
        num_clusters: int,
        n_layers: Dict[str, int] = None,
        use_kernel: bool = False,
        plan_cache: Optional[Dict] = None,
        donate: Optional[bool] = None,
        mesh: Optional[Mesh] = None
        ) -> Dict[str, Dict[str, Dict[str, Any]]]:
    """Device-resident twin of ``federate_client_params``: weights and
    cluster_labels are *device* arrays (e.g. straight out of the jitted
    stage-3/4 ``cluster_activations_jax``/``activation_weights_jax``
    chain) and the A matrix + seg_ids are assembled in-jit, so the
    round performs zero host<->device transfers of activations, labels,
    or weights. ``num_clusters`` is the static label-id bound
    (``clustering.k_selection_bound``) that fixes the segment count."""
    n_layers = n_layers or _default_n_layers()
    donate = bool(donate)
    out = {gname: dict(nets) for gname, nets in client_params.items()}
    for net, n_lay in n_layers.items():
        template = {g.name: client_params[g.name][net] for g in groups}
        plan = get_federation_plan(groups, net, n_lay, template,
                                   plan_cache=plan_cache, mesh=mesh)
        if plan.n_rows == 0:
            continue
        new_net = plan.aggregate_device(template, weights, cluster_labels,
                                        num_clusters, use_kernel=use_kernel,
                                        donate=donate)
        for g in groups:
            if g.name in new_net:
                out[g.name][net] = new_net[g.name]
    return out


def _federate_client_params_legacy(
        groups: Sequence[ProfileGroup],
        client_params: Dict[str, Dict[str, Dict[str, Any]]],
        weights: np.ndarray,
        cluster_labels: np.ndarray,
        n_layers: Dict[str, int],
        use_kernel: bool = False
        ) -> Dict[str, Dict[str, Dict[str, Any]]]:
    """Reference quadruple loop: net x layer x cluster x member, one
    gather/stack/reduce/scatter dispatch chain per combination."""
    out = jax.tree_util.tree_map(lambda x: x, client_params)  # shallow copy

    for net, n_lay in n_layers.items():
        for layer in range(n_lay):
            # owners: (group, position-in-group, global client id)
            owners: List = []
            for g in groups:
                if layer in client_owned_layers(layer_pair(g.cut, net), n_lay):
                    for pos, cid in enumerate(g.client_ids):
                        owners.append((g, pos, cid))
            if not owners:
                continue
            # aggregate per cluster over owners
            for c in np.unique(cluster_labels[[cid for _, _, cid in owners]]):
                members = [(g, pos, cid) for g, pos, cid in owners
                           if cluster_labels[cid] == c]
                w = np.array([weights[cid] for _, _, cid in members])
                if w.sum() <= 0:
                    w = np.ones_like(w)
                w = w / w.sum()
                # gather copies -> stacked [M, ...]
                copies = [jax.tree_util.tree_map(lambda x: x[pos],
                                                 client_params[g.name][net][str(layer)])
                          for g, pos, _ in members]
                stacked = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *copies)
                agg = weighted_average_stacked(stacked, jnp.asarray(w),
                                               use_kernel=use_kernel)
                # scatter aggregate back to every member
                for g, pos, _ in members:
                    cur = out[g.name][net][str(layer)]
                    out[g.name][net][str(layer)] = jax.tree_util.tree_map(
                        lambda full, a: full.at[pos].set(a.astype(full.dtype)),
                        cur, agg)
    return out


def fedavg_uniform(groups: Sequence[ProfileGroup],
                   client_params: Dict[str, Dict[str, Dict[str, Any]]],
                   sizes: np.ndarray,
                   n_layers: Dict[str, int] = None,
                   use_kernel: bool = False,
                   fused: bool = True,
                   plan_cache: Optional[Dict] = None,
                   donate: Optional[bool] = None,
                   mesh: Optional[Mesh] = None
                   ) -> Dict[str, Dict[str, Dict[str, Any]]]:
    """Vanilla FedAvg (first two federation rounds, paper §4.5): the
    degenerate single-cluster case of the fused path — one global
    cluster, weights proportional to dataset size."""
    weights = sizes.astype(np.float64) / sizes.sum()
    labels = np.zeros(len(sizes), np.int64)
    return federate_client_params(groups, client_params, weights, labels,
                                  n_layers=n_layers, use_kernel=use_kernel,
                                  fused=fused, plan_cache=plan_cache,
                                  donate=donate, mesh=mesh)
