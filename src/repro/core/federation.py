"""Clustered, KLD-weighted, layer-wise federated aggregation — Eq. (16).

Client-side segments are aggregated *within clusters*; because cuts are
heterogeneous, aggregation is **layer-wise over the layer's owners**:
for model layer l and cluster C, every client k in C that holds l
(in its head or tail) contributes its copy with weight
s_k / sum_{owners(l) in C} s_j, and all owners receive the aggregate.
Server-side segments are single shared copies trained on the combined
stream (see DESIGN.md §7 for the interpretation of the paper's global
Eq. 16 on shared parameters).

Fused round (DESIGN.md §Fused federation): a cached ``FederationPlan``
packs every profile group's stacked client segments into one
contiguous ``theta [K, D]`` f32 buffer per net (one row per client
copy, one column run per ownable layer, zero-filled where a cut does
not own the layer), builds the block-diagonal Eq.-15/16 weight matrix
on the host — one block per (layer, cluster), one row per receiving
client copy, factored exactly as ``W = B @ A`` with ``A [S, K]`` the
per-segment reduce rows and ``B`` the one-hot broadcast — and runs
flatten -> A @ theta -> broadcast-gather -> unflatten as a single
jitted computation, one Pallas ``clustered_agg`` call per net when
``use_kernel=True``. Treedefs, leaf shapes, and layer/row offsets are
cached on the plan so repeat rounds do zero host-side tree walking.
The original quadruple loop (net x layer x cluster x member) is kept
as the correctness oracle behind ``fused=False``.

Sharded round (DESIGN.md §Sharded federation): with ``mesh=`` given,
``theta``'s client (row) axis shards over the mesh's ('pod', 'data')
axes — the same "rows" placement as every population-batch tensor —
and the ``A @ theta`` cluster reduction runs as a ``shard_map``-ed
local partial-sum (the Pallas ``clustered_agg`` kernel on each
shard's row block) followed by a ``psum`` over the client axis, so
every host ends the collective holding the replicated ``[S, D]``
cluster means and ``_unflatten`` stays local. When the client count
is not divisible by the mesh (``sharding.policy.client_axes``'s
sanitize fallback) or the mesh has one device, the plan silently
uses the single-device path; ``mesh=None`` (the default) is that
path byte-for-byte.

Device-resident round (DESIGN.md §Device-resident clustering): with
stage 3+4 running on device (``clustering.cluster_activations_jax`` +
``kld.activation_weights_jax``), ``federate_client_params_device``
consumes the resulting *device* labels/weights arrays and assembles
the block-diagonal weight matrix in-jit
(``FederationPlan.device_weight_segments``): one segment row per
(layer, cluster-id < k bound), so the segment count is fixed by the
static ``k_selection_bound`` and never retraces as the selected k
moves round to round.

Chunk-streamed round (DESIGN.md §Chunk-streamed aggregation): with
``chunk_size=`` the dense ``[K, D]`` buffer is never materialized —
``aggregate_chunked`` ``lax.scan``s each profile group's stacked rows
in fixed-size chunks, contracting one ``A_c [S, c] @ theta_c [c, D]``
tile per chunk (the Pallas ``clustered_agg`` kernel when
``use_kernel=True``) into a running per-segment ``(acc [S, D],
mass [S])`` accumulator, and normalizes once at the end:
``agg = acc / mass``. Round working set is O(chunk + clusters),
independent of the client count; the re-associated summation makes
equivalence with the dense paths tolerance-bounded, not bit-exact.
With ``cohort_size``/``cohort_mask`` only the sampled cohort's
(pre-renormalized, ``kld.cohort_federation_weights_jax``) weights are
non-zero and non-members get their original params back via a
recv-select in ``_unflatten``. Sharding composes: each shard streams
its local row block of every group's leaf stacks (requires per-group
divisibility — ``sharding.policy.group_client_axes``) and one psum
merges the partial (acc, mass).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.splitting import (ProfileGroup, bucket_size,
                                  client_owned_layers, layer_pair)
from repro.sharding.policy import client_axes, group_client_axes

# Segment-count padding: round the number of (layer, cluster) blocks up
# so A's leading dim takes few distinct values (bounds jit retraces as
# the silhouette-selected k changes round to round) and stays
# sublane-aligned for the kernel.
_SEGMENT_PAD = 8


def weighted_average_stacked(stacked: Any, weights: jnp.ndarray,
                             use_kernel: bool = False) -> Any:
    """Weighted sum over the leading client axis of every leaf.
    `weights` must already be normalized over that axis."""
    if use_kernel:
        from repro.kernels import ops as kops
        return jax.tree_util.tree_map(
            lambda x: kops.weighted_agg(x, weights), stacked)
    w = weights.astype(jnp.float32)
    return jax.tree_util.tree_map(
        lambda x: jnp.einsum("k,k...->...", w, x.astype(jnp.float32)
                             ).astype(x.dtype), stacked)


# ---------------------------------------------------------------------------
# fused single-dispatch federation round
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _LeafSpec:
    shape: Tuple[int, ...]      # per-client shape (no leading K axis)
    size: int
    dtype: Any


@dataclasses.dataclass(frozen=True)
class _SegmentEntry:
    """One (group, layer) tile of the flat buffer: the group's rows x
    the layer's column run."""
    layer: int
    gname: str
    row0: int
    row1: int
    col0: int
    width: int                  # flat per-copy param count of the layer
    sid0: int                   # slice into the per-copy segment-id vec
    sid1: int
    treedef: Any
    leaves: Tuple[_LeafSpec, ...]


# ---------------------------------------------------------------------------
# bucket-padded chunk stream: one compiled program per *bucket* layout
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _ChunkedLayout:
    """Structural signature of a bucket-padded chunked round: everything
    the traced program bakes in, with every group/population size
    rounded up to its power-of-two bucket (`splitting.bucket_size`).
    Two plans with the same layout — e.g. before and after a churn
    event that stays within the buckets — share one compiled program
    (module-level ``_CHUNKED_FNS``); actual sizes enter the trace as
    runtime validity masks, not shapes. Group names appear because they
    are pytree dict keys of the params argument (a renamed group is a
    different jit-visible structure)."""
    groups: Tuple[Tuple[str, int, Tuple[int, ...]], ...]
    # (gname, bucket_size, owned layers in entries order)
    layers: Tuple[Tuple[int, int, int, Any, Tuple[_LeafSpec, ...]], ...]
    # (layer, col0, width, treedef, leaf specs), ascending layer
    n_cols: int
    S: int                      # padded segment count
    C: int                      # static cluster bound
    chunk: int
    use_kernel: bool
    with_cohort: bool


_CHUNKED_FNS: Dict[Tuple[_ChunkedLayout, bool], Callable] = {}


def _chunked_fn_cache_stats() -> Dict[str, int]:
    """Test hook: number of shared chunked programs and their summed
    jit-trace counts (cache stability across churn asserts on this)."""
    return {"programs": len(_CHUNKED_FNS),
            "traces": sum(f._cache_size() for f in _CHUNKED_FNS.values())}


def _accumulate_chunks_padded(layout: _ChunkedLayout, net_params,
                              cids_by_group, kg_by_group, w_all, lab_all,
                              part_all, zero_seg):
    """Bucket-padded twin of ``FederationPlan._accumulate_chunks``: the
    scan trip count is ``ceil(bucket / chunk)`` (static per *bucket*,
    not per population) and the actual group size ``kg`` arrives as a
    traced scalar feeding the validity mask. Padded rows carry zero
    weight, so every chunk's ``A_c`` columns for them are zero and the
    accumulator matches the unpadded stream bit-for-bit (0.0
    contributions either way)."""
    C, S, c = layout.C, layout.S, layout.chunk
    layer_ids = [l for l, _, _, _, _ in layout.layers]
    Lpos = len(layer_ids)
    acc = jnp.zeros((S, layout.n_cols), jnp.float32)
    mass = jnp.zeros(S, jnp.float32)
    for gname, Bg, owned_t in layout.groups:
        if Bg == 0:
            continue
        owned = set(owned_t)
        cids_g = cids_by_group[gname]            # [Bg] padded
        kg = kg_by_group[gname]                  # traced actual size

        def body(carry, i, gname=gname, owned=owned, Bg=Bg, kg=kg,
                 cids_g=cids_g):
            acc, mass = carry
            idx = i * c + jnp.arange(c)
            # rows past the actual size (padding and the tail-chunk
            # overhang alike) get zero weight; the gather clamps to the
            # static bucket bound.
            valid = (idx < kg).astype(jnp.float32)
            idxc = jnp.minimum(idx, Bg - 1)
            cid_c = cids_g[idxc]
            lab_c = lab_all[cid_c]
            w_c = w_all[cid_c]
            fb_c = part_all[cid_c]
            onehot = jax.nn.one_hot(lab_c, C, dtype=jnp.float32)
            parts = []
            for l, _, wdt, _, _ in layout.layers:
                if l in owned:
                    leaves = jax.tree_util.tree_leaves(
                        net_params[gname][str(l)])
                    parts.append(jnp.concatenate(
                        [jnp.take(x, idxc, axis=0).reshape(c, -1)
                         .astype(jnp.float32) for x in leaves],
                        axis=1))
                else:
                    parts.append(jnp.zeros((c, wdt), jnp.float32))
            theta_c = jnp.concatenate(parts, axis=1)         # [c, D]
            ablocks = []
            for li, l in enumerate(layer_ids):
                if l in owned:
                    w_eff = jnp.where(zero_seg[li * C + lab_c],
                                      fb_c, w_c) * valid
                    ablocks.append(onehot.T * w_eff[None, :])
                else:
                    ablocks.append(jnp.zeros((C, c), jnp.float32))
            if S > Lpos * C:
                ablocks.append(jnp.zeros((S - Lpos * C, c), jnp.float32))
            A_c = jnp.concatenate(ablocks, axis=0)           # [S, c]
            if layout.use_kernel:
                from repro.kernels import ops as kops
                part = kops.clustered_agg(A_c, theta_c)
            else:
                part = A_c @ theta_c
            return (acc + part.astype(jnp.float32),
                    mass + A_c.sum(1)), None

        (acc, mass), _ = jax.lax.scan(body, (acc, mass),
                                      jnp.arange(-(-Bg // c)))
    return acc, mass


def _unflatten_padded(layout: _ChunkedLayout, agg, seg_ids,
                      originals=None, recv=None):
    """Bucket-row twin of ``FederationPlan._unflatten``: leaves come
    back with bucket-sized leading axes (the caller slices ``[:Kg]``
    outside the jit). Padded copies gather garbage segment rows —
    harmless, they are sliced off."""
    linfo = {l: (c0, w, td, specs) for l, c0, w, td, specs in layout.layers}
    out: Dict[str, Dict[str, Any]] = {}
    sid = 0
    for gname, Bg, owned_t in layout.groups:
        for l in owned_t:
            c0, width, treedef, specs = linfo[l]
            s0, s1 = sid, sid + Bg
            sid += Bg
            block = jnp.take(agg[:, c0:c0 + width], seg_ids[s0:s1], axis=0)
            mask = None if recv is None else recv[s0:s1]
            orig_leaves = (None if originals is None else
                           jax.tree_util.tree_leaves(
                               originals[gname][str(l)]))
            leaves, off = [], 0
            for i, s in enumerate(specs):
                leaf = (block[:, off:off + s.size]
                        .reshape((Bg,) + s.shape).astype(s.dtype))
                if mask is not None:
                    m = mask.reshape((Bg,) + (1,) * len(s.shape))
                    leaf = jnp.where(m, leaf, orig_leaves[i])
                leaves.append(leaf)
                off += s.size
            out.setdefault(gname, {})[str(l)] = \
                jax.tree_util.tree_unflatten(treedef, leaves)
    return out


def _make_chunked_padded_fn(layout: _ChunkedLayout, donate: bool) -> Callable:
    """The shared bucket-padded chunked round. All per-population data
    — padded params, padded cids, actual sizes, padded copy maps,
    padded weights/labels — arrives as traced operands, so the program
    closes over nothing plan-specific and any plan with this layout
    dispatches the same compiled computation."""
    C, S = layout.C, layout.S

    def run(net_params, cids, kg, copy_lpos, copy_cid, copy_valid,
            w_all, lab_all, cohort_mask=None):
        w_all = w_all.astype(jnp.float32)
        lab_all = lab_all.astype(jnp.int32)
        part = (cohort_mask.astype(jnp.float32) if layout.with_cohort
                else jnp.ones_like(w_all))
        vf = copy_valid.astype(jnp.float32)
        seg_of_copy = copy_lpos * C + lab_all[copy_cid]
        # padded copies point at client 0 — mask them out of the
        # segment masses so the uniform-fallback detection sees only
        # real members.
        raw = jax.ops.segment_sum(w_all[copy_cid] * vf, seg_of_copy,
                                  num_segments=S)
        cnt = jax.ops.segment_sum(part[copy_cid] * vf, seg_of_copy,
                                  num_segments=S)
        zero_seg = (raw <= 0) & (cnt > 0)
        acc, mass = _accumulate_chunks_padded(
            layout, net_params, cids, kg, w_all, lab_all, part, zero_seg)
        agg = acc / jnp.maximum(mass, 1e-20)[:, None]
        seg_ids = seg_of_copy.astype(jnp.int32)
        if layout.with_cohort:
            recv = cohort_mask.astype(bool)[copy_cid]
            return _unflatten_padded(layout, agg, seg_ids,
                                     originals=net_params, recv=recv)
        return _unflatten_padded(layout, agg, seg_ids)

    if layout.with_cohort:
        def fn(net_params, cids, kg, copy_lpos, copy_cid, copy_valid,
               w_all, lab_all, cohort_mask):
            return run(net_params, cids, kg, copy_lpos, copy_cid,
                       copy_valid, w_all, lab_all, cohort_mask)
    else:
        def fn(net_params, cids, kg, copy_lpos, copy_cid, copy_valid,
               w_all, lab_all):
            return run(net_params, cids, kg, copy_lpos, copy_cid,
                       copy_valid, w_all, lab_all)
    return jax.jit(fn, donate_argnums=(0,) if donate else ())


def _pad_rows(x: jnp.ndarray, b: int) -> jnp.ndarray:
    """Zero-pad the leading axis to ``b`` rows (device-side op — safe
    under transfer_guard)."""
    n = x.shape[0]
    if n == b:
        return x
    return jnp.concatenate(
        [x, jnp.zeros((b - n,) + x.shape[1:], x.dtype)], axis=0)


class FederationPlan:
    """Host-side flattening/aggregation plan for one (net, topology).

    Flat layout: ``theta [K, D]`` — one row per client copy (groups in
    canonical order), one contiguous column run per client-ownable
    layer (zero-filled where a client's cut does not own the layer).
    The Eq.-16 round is then ``W @ theta`` with the block-diagonal
    per-(layer, cluster) weight matrix, factored exactly as
    ``W = B @ A``: ``A [S, K]`` holds one normalized reduce row per
    segment and the one-hot ``B`` broadcasts each segment's aggregate
    back to every receiving copy (a gather on the [S, D] output,
    restricted to that layer's columns — non-member columns of an
    ``A`` row are never read).

    Built once from a template of the client params; repeat rounds
    reuse the cached treedefs/shapes/offsets and the jitted aggregate
    functions (retraced only when the segment count changes).

    ``mesh``: client-axis sharding for the round. The ``[K, D]``
    buffer's rows shard over the mesh's ('pod', 'data') axes and the
    reduction becomes a shard_map partial-sum + psum; falls back to
    the single-device path when K is not divisible by the mesh (or
    the mesh is trivial). Plans are cached per mesh identity — see
    ``get_federation_plan``.

    ``chunk_size``: enables ``aggregate_chunked`` — the round streams
    each group's stacked rows in chunks of this many clients instead
    of building the dense ``[K, D]`` buffer (O(chunk + clusters)
    memory). ``cohort_size``: declared per-round participant count
    (part of the plan cache key so cohort and full-participation
    rounds never share a jitted program; the actual cohort arrives per
    call as ``cohort_mask``).
    """

    def __init__(self, groups: Sequence[ProfileGroup], net: str,
                 n_layers: int, template: Dict[str, Dict[str, Any]],
                 mesh: Optional[Mesh] = None,
                 chunk_size: Optional[int] = None,
                 cohort_size: Optional[int] = None):
        self.net = net
        self.n_layers = n_layers
        self.mesh = mesh
        self.chunk_size = None if chunk_size is None else int(chunk_size)
        self.cohort_size = None if cohort_size is None else int(cohort_size)
        # rows: one per client copy, groups in canonical order
        self._group_rows: Dict[str, Tuple[int, int]] = {}
        self.row_cids: List[int] = []
        row = 0
        for g in groups:
            self._group_rows[g.name] = (row, row + g.size)
            self.row_cids.extend(g.client_ids)
            row += g.size
        self.n_rows = row

        owned: Dict[str, List[int]] = {
            g.name: client_owned_layers(layer_pair(g.cut, net), n_layers)
            for g in groups}
        layers = sorted({l for ls in owned.values() for l in ls})

        # columns: contiguous run per client-ownable layer; leaf specs
        # must agree across groups (same layer definition).
        self._col_runs: Dict[int, Tuple[int, int]] = {}
        col = 0
        layer_specs: Dict[int, Tuple] = {}
        for l in layers:
            for g in groups:
                if l not in owned[g.name]:
                    continue
                leaves, treedef = jax.tree_util.tree_flatten(
                    template[g.name][str(l)])
                specs = tuple(_LeafSpec(
                    tuple(x.shape[1:]),
                    int(np.prod(x.shape[1:], dtype=np.int64)),
                    x.dtype) for x in leaves)
                if l not in layer_specs:
                    layer_specs[l] = (treedef, specs)
                elif layer_specs[l][1] != specs:
                    raise ValueError(
                        f"layer {l} leaf layout differs across groups "
                        f"(group {g.name})")
            width = sum(s.size for s in layer_specs[l][1])
            self._col_runs[l] = (col, width)
            col += width
        self.n_cols = col

        # entries: (group, layer) tiles + the per-copy segment-id slice
        self.entries: List[_SegmentEntry] = []
        sid = 0
        for g in groups:
            r0, r1 = self._group_rows[g.name]
            for l in owned[g.name]:
                c0, w = self._col_runs[l]
                treedef, specs = layer_specs[l]
                self.entries.append(_SegmentEntry(
                    l, g.name, r0, r1, c0, w, sid, sid + g.size,
                    treedef, specs))
                sid += g.size
        self.n_copies = sid          # receiving (layer, client copy) pairs

        # per-layer owner rows for the weight blocks
        self._layer_rows: List[Tuple[int, np.ndarray, np.ndarray]] = []
        cids_arr = np.asarray(self.row_cids, np.int64)
        for l in layers:
            rows = np.concatenate([
                np.arange(*self._group_rows[g.name]) for g in groups
                if l in owned[g.name]])
            self._layer_rows.append((l, rows, cids_arr[rows]))
        # static per-copy indices for the in-jit weight-matrix build
        # (device_weight_segments): seg_id(copy) = layer_pos * C + label
        layer_pos = {l: i for i, (l, _, _) in enumerate(self._layer_rows)}
        self._copy_layer_pos = np.zeros(max(self.n_copies, 1), np.int32)
        self._copy_cid = np.zeros(max(self.n_copies, 1), np.int32)
        for e in self.entries:
            self._copy_layer_pos[e.sid0:e.sid1] = layer_pos[e.layer]
            self._copy_cid[e.sid0:e.sid1] = cids_arr[e.row0:e.row1]
        self._owned = owned
        self._groups_order = [g.name for g in groups]
        self._agg_fns: Dict[Tuple, Callable] = {}
        # client-axis placement: the divisibility-aware sanitize drops
        # the axes (-> None -> single-device path) when K % mesh != 0
        # or the mesh axes multiply to 1.
        self._client_axes = (None if mesh is None or self.n_rows == 0
                             else client_axes(mesh, self.n_rows))
        # chunk-streamed sharding splits each group's stacked leaves on
        # their leading axis, so it needs *per-group* divisibility — a
        # stricter condition than the dense buffer's total-row check.
        group_sizes = [r1 - r0 for r0, r1 in self._group_rows.values()
                       if r1 > r0]
        self._chunk_axes = (None if mesh is None or self.chunk_size is None
                            or not group_sizes
                            else group_client_axes(mesh, group_sizes))

    # -- host-side weight matrix (Eq. 15/16 block diagonal) ----------------
    def weight_segments(self, weights: np.ndarray, cluster_labels: np.ndarray
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (A [S, K], seg_ids [n_copies]).

        ``A`` rows are the normalized per-(layer, cluster) reduce
        weights over that layer's owner rows (zero elsewhere);
        ``seg_ids`` maps every receiving (layer, client copy) pair —
        in ``entries`` order — to its segment row, i.e. the one-hot
        broadcast factor ``B`` of the block-diagonal ``W = B @ A``.
        S is padded to a multiple of _SEGMENT_PAD with zero rows
        (bounds retraces; padded segments are never gathered)."""
        rows_a: List[np.ndarray] = []
        seg_of: Dict[Tuple[int, int], int] = {}
        for l, rows, cids in self._layer_rows:
            for c in np.unique(cluster_labels[cids]):
                sel = cluster_labels[cids] == c
                w = np.asarray(weights, np.float64)[cids[sel]]
                if w.sum() <= 0:
                    w = np.ones_like(w)
                w = w / w.sum()
                a = np.zeros(self.n_rows, np.float32)
                a[rows[sel]] = w.astype(np.float32)
                seg_of[(l, int(c))] = len(rows_a)
                rows_a.append(a)
        seg_ids = np.zeros(self.n_copies, np.int32)
        for e in self.entries:
            row_cids = self.row_cids[e.row0:e.row1]
            seg_ids[e.sid0:e.sid1] = [
                seg_of[(e.layer, int(cluster_labels[cid]))]
                for cid in row_cids]
        S = max(_SEGMENT_PAD,
                -(-len(rows_a) // _SEGMENT_PAD) * _SEGMENT_PAD)
        A = np.zeros((S, self.n_rows), np.float32)
        if rows_a:
            A[:len(rows_a)] = np.stack(rows_a)
        return A, seg_ids

    # -- device-side weight matrix (traced twin, in-jit) -------------------
    def device_weight_segments(self, weights: jnp.ndarray,
                               labels: jnp.ndarray, num_clusters: int,
                               participation: Optional[jnp.ndarray] = None
                               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Traced twin of ``weight_segments``: assemble (A [S, K],
        seg_ids [n_copies]) from *device* per-client weights/labels so
        the whole round stays in one jit (DESIGN.md §Device-resident
        clustering).

        Unlike the host path, which enumerates only the clusters
        actually present, every (layer, cluster-id < num_clusters)
        pair gets a segment row — ``num_clusters`` is the static
        ``k_selection_bound``, so S is fixed and the round never
        retraces as the silhouette-selected k moves. Rows of empty
        segments are zero and never gathered (their seg_id is never
        produced); a present segment whose member weights sum to zero
        falls back to uniform over its members, like the host path.
        ``participation`` ([K] 0/1, default all-ones) restricts that
        fallback to the round's cohort — a segment whose cohort
        weight-mass underflows goes uniform over its *participating*
        members, and a segment with no participants gets a zero row
        (its non-member copies are recv-select-restored)."""
        C = int(num_clusters)
        n_seg = len(self._layer_rows) * C
        S = max(_SEGMENT_PAD, -(-n_seg // _SEGMENT_PAD) * _SEGMENT_PAD)
        A = jnp.zeros((S, self.n_rows), jnp.float32)
        w = weights.astype(jnp.float32)
        part = (jnp.ones_like(w) if participation is None
                else participation.astype(jnp.float32))
        for li, (l, rows, cids) in enumerate(self._layer_rows):
            lab = labels[cids]                                     # [R]
            onehot = jax.nn.one_hot(lab, C, dtype=jnp.float32)     # [R, C]
            raw = onehot * w[cids][:, None]
            denom = raw.sum(0)                                     # [C]
            mem = onehot * part[cids][:, None]
            cnt = mem.sum(0)
            blk = jnp.where(denom > 0,
                            raw / jnp.where(denom > 0, denom, 1.0),
                            mem / jnp.maximum(cnt, 1.0))           # [R, C]
            A = A.at[li * C:(li + 1) * C, rows].set(blk.T)
        seg_ids = (jnp.asarray(self._copy_layer_pos[:self.n_copies]) * C
                   + labels[jnp.asarray(self._copy_cid[:self.n_copies])]
                   ).astype(jnp.int32)
        return A, seg_ids

    # -- device-side flatten / unflatten (inside jit) ----------------------
    def _flatten(self, net_params: Dict[str, Dict[str, Any]]) -> jnp.ndarray:
        bufs = []
        for gname in self._groups_order:
            r0, r1 = self._group_rows[gname]
            k = r1 - r0
            parts, col = [], 0
            for l, (c0, w) in sorted(self._col_runs.items()):
                assert c0 == col
                if l in self._owned[gname]:
                    leaves = jax.tree_util.tree_leaves(net_params[gname][str(l)])
                    parts.append(jnp.concatenate(
                        [x.reshape(k, -1).astype(jnp.float32)
                         for x in leaves], axis=1))
                else:
                    parts.append(jnp.zeros((k, w), jnp.float32))
                col += w
            bufs.append(jnp.concatenate(parts, axis=1))
        return jnp.concatenate(bufs, axis=0)

    def _unflatten(self, agg: jnp.ndarray, seg_ids: jnp.ndarray,
                   originals: Optional[Dict[str, Dict[str, Any]]] = None,
                   recv: Optional[jnp.ndarray] = None
                   ) -> Dict[str, Dict[str, Any]]:
        """``recv`` ([n_copies] bool, with ``originals`` = the
        pre-round net_params): cohort recv-select — copies whose
        client did not participate this round keep their original
        leaves instead of gathering a segment aggregate they took no
        part in (which may be garbage when their whole (layer,
        cluster ∩ cohort) is empty)."""
        out: Dict[str, Dict[str, Any]] = {}
        for e in self.entries:
            block = jnp.take(agg[:, e.col0:e.col0 + e.width],
                             seg_ids[e.sid0:e.sid1], axis=0)
            mask = None if recv is None else recv[e.sid0:e.sid1]
            orig_leaves = (None if originals is None else
                           jax.tree_util.tree_leaves(
                               originals[e.gname][str(e.layer)]))
            leaves, off = [], 0
            for i, s in enumerate(e.leaves):
                leaf = (block[:, off:off + s.size]
                        .reshape((e.row1 - e.row0,) + s.shape)
                        .astype(s.dtype))
                if mask is not None:
                    m = mask.reshape((e.row1 - e.row0,)
                                     + (1,) * len(s.shape))
                    leaf = jnp.where(m, leaf, orig_leaves[i])
                leaves.append(leaf)
                off += s.size
            out.setdefault(e.gname, {})[str(e.layer)] = \
                jax.tree_util.tree_unflatten(e.treedef, leaves)
        return out

    # -- the jitted round --------------------------------------------------
    def _reduce_fn(self, use_kernel: bool) -> Callable:
        """(A [S, K], theta [K, D]) -> replicated agg [S, D] f32."""
        if self._client_axes is None:
            # single-device / fallback path: one full-K contraction.
            def reduce(A, theta):
                if use_kernel:
                    from repro.kernels import ops as kops
                    return kops.clustered_agg(A, theta)
                return A @ theta
            return reduce

        # Sharded path: theta rows and A columns split over the client
        # axis; each shard contracts its local row block (the Pallas
        # kernel runs per-shard) into a partial [S, D], and one psum
        # over the client axis leaves the full cluster means replicated
        # on every host — S*D is tiny next to K*D, and _unflatten's
        # seg_ids gather needs every segment row locally, so a
        # psum_scatter would only defer the same all-gather (DESIGN.md
        # §Sharded federation).
        axes = self._client_axes
        axis_names = (axes,) if isinstance(axes, str) else tuple(axes)

        def local_partial(a_blk, theta_blk):
            if use_kernel:
                from repro.kernels import ops as kops
                part = kops.clustered_agg(a_blk, theta_blk)
            else:
                part = a_blk @ theta_blk
            return jax.lax.psum(part.astype(jnp.float32), axis_names)

        # check_rep=False: pallas_call has no shard_map replication
        # rule; the out_spec below is still fully replicated (psum).
        return shard_map(local_partial, mesh=self.mesh,
                         in_specs=(P(None, axes), P(axes, None)),
                         out_specs=P(None, None), check_rep=False)

    def _make_agg_fn(self, use_kernel: bool, donate: bool,
                     with_cohort: bool = False) -> Callable:
        reduce = self._reduce_fn(use_kernel)
        theta_sharding = (None if self._client_axes is None else
                          NamedSharding(self.mesh, P(self._client_axes, None)))

        def core(net_params, A, seg_ids):
            theta = self._flatten(net_params)
            if theta_sharding is not None:
                theta = jax.lax.with_sharding_constraint(theta, theta_sharding)
            return reduce(A, theta)

        if with_cohort:
            def fn(net_params, A, seg_ids, recv):
                agg = core(net_params, A, seg_ids)
                return self._unflatten(agg, seg_ids,
                                       originals=net_params, recv=recv)
        else:
            def fn(net_params, A, seg_ids):
                return self._unflatten(core(net_params, A, seg_ids), seg_ids)
        return jax.jit(fn, donate_argnums=(0,) if donate else ())

    def aggregate(self, net_params: Dict[str, Dict[str, Any]],
                  A: np.ndarray, seg_ids: np.ndarray,
                  use_kernel: bool = False,
                  donate: bool = False,
                  cohort_mask: Optional[np.ndarray] = None
                  ) -> Dict[str, Dict[str, Any]]:
        key = (use_kernel, donate, cohort_mask is not None)
        if key not in self._agg_fns:
            self._agg_fns[key] = self._make_agg_fn(
                use_kernel, donate, cohort_mask is not None)
        args = [net_params, jnp.asarray(A, jnp.float32),
                jnp.asarray(seg_ids, jnp.int32)]
        if cohort_mask is not None:
            recv = np.asarray(cohort_mask, bool)[
                self._copy_cid[:self.n_copies]]
            args.append(jnp.asarray(recv))
        return self._agg_fns[key](*args)

    def _make_agg_device_fn(self, num_clusters: int, use_kernel: bool,
                            donate: bool, with_cohort: bool = False
                            ) -> Callable:
        reduce = self._reduce_fn(use_kernel)
        theta_sharding = (None if self._client_axes is None else
                          NamedSharding(self.mesh, P(self._client_axes, None)))
        copy_cid = jnp.asarray(self._copy_cid[:self.n_copies])

        def core(net_params, weights, labels, participation=None):
            A, seg_ids = self.device_weight_segments(
                weights, labels, num_clusters, participation=participation)
            theta = self._flatten(net_params)
            if theta_sharding is not None:
                theta = jax.lax.with_sharding_constraint(theta, theta_sharding)
            return reduce(A, theta), seg_ids

        if with_cohort:
            def fn(net_params, weights, labels, cohort_mask):
                agg, seg_ids = core(net_params, weights, labels,
                                    cohort_mask.astype(jnp.float32))
                recv = cohort_mask.astype(bool)[copy_cid]
                return self._unflatten(agg, seg_ids,
                                       originals=net_params, recv=recv)
        else:
            def fn(net_params, weights, labels):
                agg, seg_ids = core(net_params, weights, labels)
                return self._unflatten(agg, seg_ids)
        return jax.jit(fn, donate_argnums=(0,) if donate else ())

    def aggregate_device(self, net_params: Dict[str, Dict[str, Any]],
                         weights: jnp.ndarray, labels: jnp.ndarray,
                         num_clusters: int, use_kernel: bool = False,
                         donate: bool = False,
                         cohort_mask: Optional[jnp.ndarray] = None
                         ) -> Dict[str, Dict[str, Any]]:
        """Device-resident round: weights/labels are per-client device
        arrays (label ids < the static ``num_clusters`` bound); the
        Eq.-15/16 weight matrix is assembled in-jit — no host numpy
        between the inputs and the aggregated params. weights/labels
        are never donated (the caller reuses them across nets).
        ``cohort_mask`` ([K] bool, weights pre-renormalized over the
        cohort — ``kld.cohort_federation_weights_jax``): non-members
        keep their original params via the recv-select."""
        key = ("device", int(num_clusters), use_kernel, donate,
               cohort_mask is not None)
        if key not in self._agg_fns:
            self._agg_fns[key] = self._make_agg_device_fn(
                int(num_clusters), use_kernel, donate,
                cohort_mask is not None)
        if cohort_mask is not None:
            return self._agg_fns[key](net_params, weights, labels,
                                      cohort_mask)
        return self._agg_fns[key](net_params, weights, labels)

    # -- chunk-streamed round (DESIGN.md §Chunk-streamed aggregation) ------
    def _accumulate_chunks(self, net_params, cids_by_group, w_all, lab_all,
                           part_all, zero_seg, num_clusters: int, chunk: int,
                           use_kernel: bool):
        """Stream every group's stacked rows in fixed-size chunks,
        contracting one ``A_c [S, c] @ theta_c [c, D]`` tile per chunk
        into the scan-carried ``(acc [S, D], mass [S])`` accumulator
        (XLA donates the carry in place). ``zero_seg`` [S] marks
        segments whose raw weight mass is zero but have participating
        members — their members switch to their ``part_all`` value
        (1.0 for participants, 0 outside the cohort), reproducing the
        dense paths' uniform-over-participants fallback without
        knowing the total mid-stream. Runs on the *local* row block
        under shard_map (leaf leading dims and cids are shard-local
        there); returns unnormalized (acc, mass)."""
        C = int(num_clusters)
        Lpos = len(self._layer_rows)
        S = zero_seg.shape[0]
        c = int(chunk)
        sorted_runs = sorted(self._col_runs.items())
        acc = jnp.zeros((S, self.n_cols), jnp.float32)
        mass = jnp.zeros(S, jnp.float32)
        for gname in self._groups_order:
            cids_g = cids_by_group[gname]
            Kg = int(cids_g.shape[0])
            if Kg == 0:
                continue
            owned = self._owned[gname]

            def body(carry, i, gname=gname, cids_g=cids_g, Kg=Kg,
                     owned=owned):
                acc, mass = carry
                idx = i * c + jnp.arange(c)
                # JAX clamps out-of-range dynamic indices, which would
                # double-count the last row on the tail chunk — clamp
                # explicitly and zero the weights of the overhang.
                valid = (idx < Kg).astype(jnp.float32)
                idxc = jnp.minimum(idx, Kg - 1)
                cid_c = cids_g[idxc]
                lab_c = lab_all[cid_c]
                w_c = w_all[cid_c]
                fb_c = part_all[cid_c]
                onehot = jax.nn.one_hot(lab_c, C, dtype=jnp.float32)
                parts = []
                for l, (c0, wdt) in sorted_runs:
                    if l in owned:
                        leaves = jax.tree_util.tree_leaves(
                            net_params[gname][str(l)])
                        parts.append(jnp.concatenate(
                            [jnp.take(x, idxc, axis=0).reshape(c, -1)
                             .astype(jnp.float32) for x in leaves],
                            axis=1))
                    else:
                        parts.append(jnp.zeros((c, wdt), jnp.float32))
                theta_c = jnp.concatenate(parts, axis=1)         # [c, D]
                ablocks = []
                for li, (l, _, _) in enumerate(self._layer_rows):
                    if l in owned:
                        w_eff = jnp.where(zero_seg[li * C + lab_c],
                                          fb_c, w_c) * valid
                        ablocks.append(onehot.T * w_eff[None, :])
                    else:
                        ablocks.append(jnp.zeros((C, c), jnp.float32))
                if S > Lpos * C:
                    ablocks.append(jnp.zeros((S - Lpos * C, c),
                                             jnp.float32))
                A_c = jnp.concatenate(ablocks, axis=0)           # [S, c]
                if use_kernel:
                    from repro.kernels import ops as kops
                    part = kops.clustered_agg(A_c, theta_c)
                else:
                    part = A_c @ theta_c
                return (acc + part.astype(jnp.float32),
                        mass + A_c.sum(1)), None

            (acc, mass), _ = jax.lax.scan(body, (acc, mass),
                                          jnp.arange(-(-Kg // c)))
        return acc, mass

    def _make_agg_chunked_fn(self, num_clusters: int, use_kernel: bool,
                             donate: bool, with_cohort: bool) -> Callable:
        C = int(num_clusters)
        chunk = int(self.chunk_size)
        n_seg = len(self._layer_rows) * C
        S = max(_SEGMENT_PAD, -(-n_seg // _SEGMENT_PAD) * _SEGMENT_PAD)
        n_cop = self.n_copies
        copy_lpos = jnp.asarray(self._copy_layer_pos[:n_cop])
        copy_cid = jnp.asarray(self._copy_cid[:n_cop])
        cids_np = {g: np.asarray(self.row_cids[r0:r1], np.int32)
                   for g, (r0, r1) in self._group_rows.items()}
        axes = self._chunk_axes
        axis_names = (() if axes is None else
                      ((axes,) if isinstance(axes, str) else tuple(axes)))

        def run(net_params, w_all, lab_all, cohort_mask=None):
            w_all = w_all.astype(jnp.float32)
            lab_all = lab_all.astype(jnp.int32)
            # participation vector: 1.0 for clients in the round. The
            # uniform fallback for a segment whose weight mass
            # underflows goes uniform over *participants* only, and a
            # (layer, cluster) with an empty cohort keeps mass 0 (its
            # copies are recv-select-restored) — matching
            # device_weight_segments' participation semantics.
            part = (cohort_mask.astype(jnp.float32) if with_cohort
                    else jnp.ones_like(w_all))
            seg_of_copy = copy_lpos * C + lab_all[copy_cid]
            raw = jax.ops.segment_sum(w_all[copy_cid], seg_of_copy,
                                      num_segments=S)
            cnt = jax.ops.segment_sum(part[copy_cid], seg_of_copy,
                                      num_segments=S)
            zero_seg = (raw <= 0) & (cnt > 0)
            cids = {g: jnp.asarray(v) for g, v in cids_np.items()}
            if axes is None:
                acc, mass = self._accumulate_chunks(
                    net_params, cids, w_all, lab_all, part, zero_seg,
                    C, chunk, use_kernel)
            else:
                # Sharded stream: each shard holds a row block of every
                # group's leaf stack (and the matching cids slice),
                # scans its local chunks, and one psum merges the tiny
                # (acc, mass) — same collective shape as the dense
                # sharded reduction. check_rep=False: pallas_call has
                # no shard_map replication rule.
                def local(net_p, cids_l, w, lab, pt, zs):
                    a, m = self._accumulate_chunks(
                        net_p, cids_l, w, lab, pt, zs, C, chunk,
                        use_kernel)
                    return (jax.lax.psum(a, axis_names),
                            jax.lax.psum(m, axis_names))
                p_specs = jax.tree_util.tree_map(
                    lambda x: P(axes, *([None] * (x.ndim - 1))),
                    net_params)
                c_specs = {g: P(axes) for g in cids}
                acc, mass = shard_map(
                    local, mesh=self.mesh,
                    in_specs=(p_specs, c_specs, P(None), P(None),
                              P(None), P(None)),
                    out_specs=(P(None, None), P(None)),
                    check_rep=False)(net_params, cids, w_all, lab_all,
                                     part, zero_seg)
            agg = acc / jnp.maximum(mass, 1e-20)[:, None]
            seg_ids = seg_of_copy.astype(jnp.int32)
            if with_cohort:
                recv = cohort_mask.astype(bool)[copy_cid]
                return self._unflatten(agg, seg_ids,
                                       originals=net_params, recv=recv)
            return self._unflatten(agg, seg_ids)

        if with_cohort:
            def fn(net_params, weights, labels, cohort_mask):
                return run(net_params, weights, labels, cohort_mask)
        else:
            def fn(net_params, weights, labels):
                return run(net_params, weights, labels)
        return jax.jit(fn, donate_argnums=(0,) if donate else ())

    def _chunked_layout(self, num_clusters: int, use_kernel: bool,
                        with_cohort: bool) -> _ChunkedLayout:
        """Bucket-padded structural signature of this plan's chunked
        round (see ``_ChunkedLayout``)."""
        C = int(num_clusters)
        n_seg = len(self._layer_rows) * C
        S = max(_SEGMENT_PAD, -(-n_seg // _SEGMENT_PAD) * _SEGMENT_PAD)
        by_layer: Dict[int, Tuple] = {}
        for e in self.entries:
            by_layer.setdefault(e.layer, (e.col0, e.width, e.treedef,
                                          e.leaves))
        layers = tuple((l,) + by_layer[l] for l in sorted(by_layer))
        groups = tuple((g, bucket_size(r1 - r0), tuple(self._owned[g]))
                       for g, (r0, r1) in self._group_rows.items())
        return _ChunkedLayout(groups, layers, self.n_cols, S, C,
                              int(self.chunk_size), use_kernel,
                              with_cohort)

    def _chunked_operands(self):
        """Per-plan device operands of the shared chunked program:
        bucket-padded group cids, traced actual sizes, and the
        bucket-strided copy->(layer_pos, cid, valid) maps. Built once
        per plan and cached as device arrays so repeat rounds do zero
        host->device transfers (transfer_guard-safe after warm-up)."""
        ops = getattr(self, "_chunk_ops", None)
        if ops is not None:
            return ops
        layer_pos = {l: i for i, (l, _, _) in enumerate(self._layer_rows)}
        cids_arr = np.asarray(self.row_cids, np.int64)
        cids_pad: Dict[str, jnp.ndarray] = {}
        kg: Dict[str, jnp.ndarray] = {}
        lpos_l, cid_l, valid_l = [], [], []
        for g, (r0, r1) in self._group_rows.items():
            Kg = r1 - r0
            Bg = bucket_size(Kg)
            c = np.zeros(Bg, np.int32)
            c[:Kg] = cids_arr[r0:r1]
            cids_pad[g] = jnp.asarray(c)
            kg[g] = jnp.asarray(Kg, jnp.int32)
            for l in self._owned[g]:
                lpos_l.append(np.full(Bg, layer_pos[l], np.int32))
                cc = np.zeros(Bg, np.int32)
                cc[:Kg] = cids_arr[r0:r1]
                cid_l.append(cc)
                vv = np.zeros(Bg, bool)
                vv[:Kg] = True
                valid_l.append(vv)

        def cat(xs, dtype):
            return jnp.asarray(np.concatenate(xs) if xs
                               else np.zeros(0, dtype))
        self._chunk_ops = (cids_pad, kg, cat(lpos_l, np.int32),
                           cat(cid_l, np.int32), cat(valid_l, bool))
        return self._chunk_ops

    def aggregate_chunked(self, net_params: Dict[str, Dict[str, Any]],
                          weights: jnp.ndarray, labels: jnp.ndarray,
                          num_clusters: int, use_kernel: bool = False,
                          donate: bool = False,
                          cohort_mask: Optional[jnp.ndarray] = None
                          ) -> Dict[str, Dict[str, Any]]:
        """Chunk-streamed round (requires the plan to be built with
        ``chunk_size=``): same signature semantics as
        ``aggregate_device`` but the dense ``[K, D]`` buffer is never
        built — partial sums + weight masses accumulate over a
        ``lax.scan`` of client chunks and a single normalize at the
        end divides them out. Equivalence with the dense paths is
        tolerance-bounded (re-associated f32 summation), not
        bit-exact.

        Unsharded rounds run the *shared* bucket-padded program
        (module-level ``_CHUNKED_FNS``, one per ``_ChunkedLayout``):
        group sizes pad to power-of-two buckets, scan trip counts are
        per-bucket, and actual sizes arrive as traced validity masks —
        so a churned population whose per-group counts stay within the
        buckets reuses the compiled round instead of retracing.
        Numerically identical to the unpadded stream (padded rows carry
        zero weight). The sharded stream (``_chunk_axes``) keeps its
        per-plan program: shard_map bakes the mesh and per-shard row
        blocks into the closure, and padding would break the per-group
        divisibility contract."""
        if self.chunk_size is None:
            raise ValueError("plan was built without chunk_size; pass "
                             "chunk_size= to get_federation_plan")
        if self._chunk_axes is not None:
            key = ("chunked", int(num_clusters), use_kernel, donate,
                   cohort_mask is not None)
            if key not in self._agg_fns:
                self._agg_fns[key] = self._make_agg_chunked_fn(
                    int(num_clusters), use_kernel, donate,
                    cohort_mask is not None)
            if cohort_mask is not None:
                return self._agg_fns[key](net_params, weights, labels,
                                          cohort_mask)
            return self._agg_fns[key](net_params, weights, labels)

        layout = self._chunked_layout(int(num_clusters), use_kernel,
                                      cohort_mask is not None)
        fkey = (layout, donate)
        fn = _CHUNKED_FNS.get(fkey)
        if fn is None:
            fn = _CHUNKED_FNS[fkey] = _make_chunked_padded_fn(layout,
                                                              donate)
        cids_pad, kg, lpos, cid, valid = self._chunked_operands()
        KB = bucket_size(int(weights.shape[0]))
        params_pad: Dict[str, Dict[str, Any]] = {}
        for gname, Bg, _ in layout.groups:
            params_pad[gname] = {
                l: jax.tree_util.tree_map(
                    lambda x: _pad_rows(jnp.asarray(x), Bg), tree)
                for l, tree in net_params[gname].items()}
        args = [params_pad, cids_pad, kg, lpos, cid, valid,
                _pad_rows(jnp.asarray(weights), KB),
                _pad_rows(jnp.asarray(labels), KB)]
        if cohort_mask is not None:
            args.append(_pad_rows(jnp.asarray(cohort_mask), KB))
        out_pad = fn(*args)
        out: Dict[str, Dict[str, Any]] = {}
        for gname, Bg, _ in layout.groups:
            Kg = self._group_rows[gname][1] - self._group_rows[gname][0]
            if Bg == Kg:
                out[gname] = out_pad[gname]
            else:
                out[gname] = {
                    l: jax.tree_util.tree_map(lambda x: x[:Kg], tree)
                    for l, tree in out_pad[gname].items()}
        return out

    # -- memory envelopes --------------------------------------------------
    def dense_buffer_bytes(self) -> int:
        """f32 bytes of the dense ``theta [K, D]`` flat client buffer
        the non-chunked paths materialize (the O(clients) term the
        chunk stream eliminates)."""
        return 4 * self.n_rows * self.n_cols

    def chunked_buffer_bytes(self, num_clusters: int) -> int:
        """f32 bytes of the chunk stream's working set: one
        ``theta_c [c, D]`` + ``A_c [S, c]`` tile plus the carried
        ``(acc [S, D], mass [S])`` — O(chunk + clusters), independent
        of the client count."""
        if self.chunk_size is None:
            raise ValueError("plan was built without chunk_size")
        n_seg = len(self._layer_rows) * int(num_clusters)
        S = max(_SEGMENT_PAD, -(-n_seg // _SEGMENT_PAD) * _SEGMENT_PAD)
        c = int(self.chunk_size)
        return 4 * (c * self.n_cols + S * c + S * self.n_cols + S)


_PLAN_CACHE: Dict[Tuple, FederationPlan] = {}


def _plan_key(groups: Sequence[ProfileGroup], net: str, n_layers: int,
              template: Dict[str, Dict[str, Any]],
              mesh: Optional[Mesh] = None,
              chunk_size: Optional[int] = None,
              cohort_size: Optional[int] = None) -> Tuple:
    # The leaf-layout fingerprint guards the shared cache against two
    # same-topology populations with differently-shaped layer params
    # (walking ~100 aval objects per round is noise next to the round).
    # Mesh identity is part of the key: a plan bakes its shard_map /
    # sharding constraints to one mesh, so the same topology on a
    # different mesh (or none) must get its own plan (jax.sharding.Mesh
    # hashes by device assignment + axis names). (chunk_size,
    # cohort_size) join it for the same reason: the chunked scan and
    # the cohort recv-select are baked into the plan's jitted programs,
    # so a dense full-participation round must never reuse a chunked /
    # cohort plan (or vice versa).
    layout = tuple(
        (g.name, tuple(
            (l, tuple((tuple(x.shape), str(x.dtype)) for x in
                      jax.tree_util.tree_leaves(tree)))
            for l, tree in sorted(template[g.name].items())))
        for g in groups)
    return (net, n_layers, tuple(
        (g.name, g.cut.as_tuple(), tuple(g.client_ids)) for g in groups),
        layout, mesh, chunk_size, cohort_size)


def get_federation_plan(groups: Sequence[ProfileGroup], net: str,
                        n_layers: int,
                        template: Dict[str, Dict[str, Any]],
                        plan_cache: Optional[Dict] = None,
                        mesh: Optional[Mesh] = None,
                        chunk_size: Optional[int] = None,
                        cohort_size: Optional[int] = None) -> FederationPlan:
    cache = _PLAN_CACHE if plan_cache is None else plan_cache
    key = _plan_key(groups, net, n_layers, template, mesh,
                    chunk_size=chunk_size, cohort_size=cohort_size)
    if key not in cache:
        cache[key] = FederationPlan(groups, net, n_layers, template,
                                    mesh=mesh, chunk_size=chunk_size,
                                    cohort_size=cohort_size)
    return cache[key]


def _default_n_layers() -> Dict[str, int]:
    """Per-net layer counts derived from the model depth (lazy import:
    federation must stay importable without the models package in the
    graph at module load). A hardcoded {net: 5} here would silently
    mis-plan the flat buffer if the layer defs ever grow."""
    from repro.models.gan import DISC_LAYER_DEFS, GEN_LAYER_DEFS
    return {"G": len(GEN_LAYER_DEFS), "D": len(DISC_LAYER_DEFS)}


def donate_default() -> bool:
    """Whether a caller that *owns* its buffers (replaces every
    reference after the round, like the trainer) should donate them.
    CPU XLA ignores donation (with a warning per call) — only donate
    where the runtime can actually alias the buffers."""
    return jax.default_backend() in ("tpu", "gpu")


def federate_client_params(groups: Sequence[ProfileGroup],
                           client_params: Dict[str, Dict[str, Dict[str, Any]]],
                           weights: np.ndarray,
                           cluster_labels: np.ndarray,
                           n_layers: Dict[str, int] = None,
                           use_kernel: bool = False,
                           fused: bool = True,
                           plan_cache: Optional[Dict] = None,
                           donate: Optional[bool] = None,
                           mesh: Optional[Mesh] = None,
                           chunk_size: Optional[int] = None,
                           cohort_mask: Optional[np.ndarray] = None
                           ) -> Dict[str, Dict[str, Dict[str, Any]]]:
    """Aggregate client-held layers cluster-wise.

    client_params: {group.name: {net: {str(layer): stacked pytree}}}
    weights: Eq.-15 intra-cluster weights, indexed by global client id.
    cluster_labels: cluster id per global client id.
    fused=True runs the single-dispatch flat-buffer path (one jitted
    call per net; Pallas kernel when use_kernel); fused=False runs the
    legacy per-(layer, cluster, leaf) loop (correctness oracle —
    full-participation dense rounds only).
    donate=True aliases the input buffers into the jitted round —
    only safe when the caller drops every reference to client_params
    afterwards (the trainer does; pass ``donate_default()``). The
    default never donates, so repeated calls on the same params are
    always valid.
    mesh=Mesh(...) shards the flat client buffer's rows over the
    mesh's ('pod', 'data') axes and reduces via shard_map partial-sums
    + psum (see FederationPlan); ``None`` keeps today's single-device
    path unchanged. Non-divisible client counts fall back silently.
    chunk_size=c streams the round in c-client chunks instead of
    building the dense [K, D] buffer (tolerance-bounded equivalence —
    see FederationPlan.aggregate_chunked). cohort_mask ([n_clients]
    bool) runs a sampled-cohort round: ``weights`` must already be
    renormalized over the cohort (``kld.cohort_federation_weights``,
    zero outside it) and non-members keep their original params.
    Returns a new client_params with aggregated copies broadcast back.
    """
    n_layers = n_layers or _default_n_layers()
    if not fused:
        if chunk_size is not None or cohort_mask is not None:
            raise ValueError("the legacy loop is a full-participation "
                             "dense oracle: chunk_size/cohort_mask "
                             "require fused=True")
        return _federate_client_params_legacy(
            groups, client_params, weights, cluster_labels,
            n_layers=n_layers, use_kernel=use_kernel)
    if donate is None:
        donate = False
    weights = np.asarray(weights)
    cluster_labels = np.asarray(cluster_labels)
    cohort_size = (None if cohort_mask is None
                   else int(np.asarray(cohort_mask, bool).sum()))
    out = {gname: dict(nets) for gname, nets in client_params.items()}
    for net, n_lay in n_layers.items():
        template = {g.name: client_params[g.name][net] for g in groups}
        plan = get_federation_plan(groups, net, n_lay, template,
                                   plan_cache=plan_cache, mesh=mesh,
                                   chunk_size=chunk_size,
                                   cohort_size=cohort_size)
        if plan.n_rows == 0:
            continue
        if chunk_size is not None:
            new_net = plan.aggregate_chunked(
                template, jnp.asarray(weights, jnp.float32),
                jnp.asarray(cluster_labels, jnp.int32),
                num_clusters=int(cluster_labels.max()) + 1,
                use_kernel=use_kernel, donate=donate,
                cohort_mask=None if cohort_mask is None
                else jnp.asarray(np.asarray(cohort_mask, bool)))
        else:
            A, seg_ids = plan.weight_segments(weights, cluster_labels)
            new_net = plan.aggregate(template, A, seg_ids,
                                     use_kernel=use_kernel, donate=donate,
                                     cohort_mask=cohort_mask)
        for g in groups:
            if g.name in new_net:
                out[g.name][net] = new_net[g.name]
    return out


def federate_client_params_device(
        groups: Sequence[ProfileGroup],
        client_params: Dict[str, Dict[str, Dict[str, Any]]],
        weights: jnp.ndarray,
        cluster_labels: jnp.ndarray,
        num_clusters: int,
        n_layers: Dict[str, int] = None,
        use_kernel: bool = False,
        plan_cache: Optional[Dict] = None,
        donate: Optional[bool] = None,
        mesh: Optional[Mesh] = None,
        chunk_size: Optional[int] = None,
        cohort_mask: Optional[jnp.ndarray] = None,
        cohort_size: Optional[int] = None
        ) -> Dict[str, Dict[str, Dict[str, Any]]]:
    """Device-resident twin of ``federate_client_params``: weights and
    cluster_labels are *device* arrays (e.g. straight out of the jitted
    stage-3/4 ``cluster_activations_jax``/``activation_weights_jax``
    chain) and the A matrix + seg_ids are assembled in-jit, so the
    round performs zero host<->device transfers of activations, labels,
    or weights. ``num_clusters`` is the static label-id bound
    (``clustering.k_selection_bound``) that fixes the segment count.
    chunk_size streams the round (``aggregate_chunked``); cohort_mask
    ([K] bool device array, weights pre-renormalized over the cohort)
    runs a sampled-cohort round — pass the static ``cohort_size``
    alongside so the plan cache separates cohort programs (the mask
    itself never leaves the device)."""
    n_layers = n_layers or _default_n_layers()
    donate = bool(donate)
    out = {gname: dict(nets) for gname, nets in client_params.items()}
    for net, n_lay in n_layers.items():
        template = {g.name: client_params[g.name][net] for g in groups}
        plan = get_federation_plan(groups, net, n_lay, template,
                                   plan_cache=plan_cache, mesh=mesh,
                                   chunk_size=chunk_size,
                                   cohort_size=cohort_size)
        if plan.n_rows == 0:
            continue
        if chunk_size is not None:
            new_net = plan.aggregate_chunked(
                template, weights, cluster_labels, num_clusters,
                use_kernel=use_kernel, donate=donate,
                cohort_mask=cohort_mask)
        else:
            new_net = plan.aggregate_device(
                template, weights, cluster_labels, num_clusters,
                use_kernel=use_kernel, donate=donate,
                cohort_mask=cohort_mask)
        for g in groups:
            if g.name in new_net:
                out[g.name][net] = new_net[g.name]
    return out


def _federate_client_params_legacy(
        groups: Sequence[ProfileGroup],
        client_params: Dict[str, Dict[str, Dict[str, Any]]],
        weights: np.ndarray,
        cluster_labels: np.ndarray,
        n_layers: Dict[str, int],
        use_kernel: bool = False
        ) -> Dict[str, Dict[str, Dict[str, Any]]]:
    """Reference quadruple loop: net x layer x cluster x member, one
    gather/stack/reduce/scatter dispatch chain per combination."""
    out = jax.tree_util.tree_map(lambda x: x, client_params)  # shallow copy

    for net, n_lay in n_layers.items():
        for layer in range(n_lay):
            # owners: (group, position-in-group, global client id)
            owners: List = []
            for g in groups:
                if layer in client_owned_layers(layer_pair(g.cut, net), n_lay):
                    for pos, cid in enumerate(g.client_ids):
                        owners.append((g, pos, cid))
            if not owners:
                continue
            # aggregate per cluster over owners
            for c in np.unique(cluster_labels[[cid for _, _, cid in owners]]):
                members = [(g, pos, cid) for g, pos, cid in owners
                           if cluster_labels[cid] == c]
                w = np.array([weights[cid] for _, _, cid in members])
                if w.sum() <= 0:
                    w = np.ones_like(w)
                w = w / w.sum()
                # gather copies -> stacked [M, ...]
                copies = [jax.tree_util.tree_map(lambda x: x[pos],
                                                 client_params[g.name][net][str(layer)])
                          for g, pos, _ in members]
                stacked = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *copies)
                agg = weighted_average_stacked(stacked, jnp.asarray(w),
                                               use_kernel=use_kernel)
                # scatter aggregate back to every member
                for g, pos, _ in members:
                    cur = out[g.name][net][str(layer)]
                    out[g.name][net][str(layer)] = jax.tree_util.tree_map(
                        lambda full, a: full.at[pos].set(a.astype(full.dtype)),
                        cur, agg)
    return out


def fedavg_uniform(groups: Sequence[ProfileGroup],
                   client_params: Dict[str, Dict[str, Dict[str, Any]]],
                   sizes: np.ndarray,
                   n_layers: Dict[str, int] = None,
                   use_kernel: bool = False,
                   fused: bool = True,
                   plan_cache: Optional[Dict] = None,
                   donate: Optional[bool] = None,
                   mesh: Optional[Mesh] = None,
                   chunk_size: Optional[int] = None,
                   cohort_mask: Optional[np.ndarray] = None
                   ) -> Dict[str, Dict[str, Dict[str, Any]]]:
    """Vanilla FedAvg (first two federation rounds, paper §4.5): the
    degenerate single-cluster case of the fused path — one global
    cluster, weights proportional to dataset size. With cohort_mask,
    sizes renormalize over the cohort and non-members sit the round
    out (same recv-select as the clustered cohort round)."""
    sizes = np.asarray(sizes, np.float64)
    if cohort_mask is not None:
        sized = sizes * np.asarray(cohort_mask, bool)
        weights = sized / sized.sum()
    else:
        weights = sizes / sizes.sum()
    labels = np.zeros(len(sizes), np.int64)
    return federate_client_params(groups, client_params, weights, labels,
                                  n_layers=n_layers, use_kernel=use_kernel,
                                  fused=fused, plan_cache=plan_cache,
                                  donate=donate, mesh=mesh,
                                  chunk_size=chunk_size,
                                  cohort_mask=cohort_mask)
