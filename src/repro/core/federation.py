"""Clustered, KLD-weighted, layer-wise federated aggregation — Eq. (16).

Client-side segments are aggregated *within clusters*; because cuts are
heterogeneous, aggregation is **layer-wise over the layer's owners**:
for model layer l and cluster C, every client k in C that holds l
(in its head or tail) contributes its copy with weight
s_k / sum_{owners(l) in C} s_j, and all owners receive the aggregate.
Server-side segments are single shared copies trained on the combined
stream (see DESIGN.md §7 for the interpretation of the paper's global
Eq. 16 on shared parameters).

The weighted reduction over the stacked client axis is the compute hot
spot; `use_kernel=True` routes it through the Pallas `weighted_agg`
kernel (interpret mode on CPU).
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.splitting import ProfileGroup, client_owned_layers, layer_pair


def weighted_average_stacked(stacked: Any, weights: jnp.ndarray,
                             use_kernel: bool = False) -> Any:
    """Weighted sum over the leading client axis of every leaf.
    `weights` must already be normalized over that axis."""
    if use_kernel:
        from repro.kernels import ops as kops
        return jax.tree_util.tree_map(
            lambda x: kops.weighted_agg(x, weights), stacked)
    w = weights.astype(jnp.float32)
    return jax.tree_util.tree_map(
        lambda x: jnp.einsum("k,k...->...", w, x.astype(jnp.float32)
                             ).astype(x.dtype), stacked)


def federate_client_params(groups: Sequence[ProfileGroup],
                           client_params: Dict[str, Dict[str, Dict[str, Any]]],
                           weights: np.ndarray,
                           cluster_labels: np.ndarray,
                           n_layers: Dict[str, int] = None,
                           use_kernel: bool = False
                           ) -> Dict[str, Dict[str, Dict[str, Any]]]:
    """Aggregate client-held layers cluster-wise.

    client_params: {group.name: {net: {str(layer): stacked pytree}}}
    weights: Eq.-15 intra-cluster weights, indexed by global client id.
    cluster_labels: cluster id per global client id.
    Returns a new client_params with aggregated copies broadcast back.
    """
    n_layers = n_layers or {"G": 5, "D": 5}
    out = jax.tree_util.tree_map(lambda x: x, client_params)  # shallow copy

    for net, n_lay in n_layers.items():
        for layer in range(n_lay):
            # owners: (group, position-in-group, global client id)
            owners: List = []
            for g in groups:
                if layer in client_owned_layers(layer_pair(g.cut, net), n_lay):
                    for pos, cid in enumerate(g.client_ids):
                        owners.append((g, pos, cid))
            if not owners:
                continue
            # aggregate per cluster over owners
            for c in np.unique(cluster_labels[[cid for _, _, cid in owners]]):
                members = [(g, pos, cid) for g, pos, cid in owners
                           if cluster_labels[cid] == c]
                w = np.array([weights[cid] for _, _, cid in members])
                if w.sum() <= 0:
                    w = np.ones_like(w)
                w = w / w.sum()
                # gather copies -> stacked [M, ...]
                copies = [jax.tree_util.tree_map(lambda x: x[pos],
                                                 client_params[g.name][net][str(layer)])
                          for g, pos, _ in members]
                stacked = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *copies)
                agg = weighted_average_stacked(stacked, jnp.asarray(w),
                                               use_kernel=use_kernel)
                # scatter aggregate back to every member
                for g, pos, _ in members:
                    cur = out[g.name][net][str(layer)]
                    out[g.name][net][str(layer)] = jax.tree_util.tree_map(
                        lambda full, a: full.at[pos].set(a.astype(full.dtype)),
                        cur, agg)
    return out


def fedavg_uniform(groups: Sequence[ProfileGroup],
                   client_params: Dict[str, Dict[str, Dict[str, Any]]],
                   sizes: np.ndarray,
                   n_layers: Dict[str, int] = None
                   ) -> Dict[str, Dict[str, Dict[str, Any]]]:
    """Vanilla FedAvg (first two federation rounds, paper §4.5):
    single global cluster, weights proportional to dataset size."""
    weights = sizes.astype(np.float64) / sizes.sum()
    labels = np.zeros(len(sizes), np.int64)
    return federate_client_params(groups, client_params, weights, labels,
                                  n_layers=n_layers)
