"""Cut/segment machinery for layered models — paper §4.1/§4.4.

A *layered model* is an ordered list of (init, apply) layer pairs (see
`repro.models.gan`). A `Cut` splits each network into head/server/tail.
Clients are grouped into `ProfileGroup`s (appendix D): all clients in a
group share a device profile and therefore a cut, so their client-side
segments stack into leading-axis-K_p pytrees that we vmap over.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.latency import Cut, DeviceProfile


@dataclasses.dataclass
class ProfileGroup:
    """A set of clients sharing one device profile and one cut."""
    name: str
    profile: DeviceProfile
    cut: Cut
    client_ids: List[int]          # global client indices, canonical order

    @property
    def size(self) -> int:
        return len(self.client_ids)


def group_by_profile(devices: Sequence[DeviceProfile],
                     cuts: Sequence[Cut]) -> List[ProfileGroup]:
    """Group clients whose (profile, cut) coincide. Client order inside a
    group follows global order; groups sorted by name for determinism."""
    table: Dict[Tuple, ProfileGroup] = {}
    for cid, (dev, cut) in enumerate(zip(devices, cuts)):
        key = (dev.name, cut.as_tuple())
        if key not in table:
            table[key] = ProfileGroup(f"{dev.name}|{cut.as_tuple()}", dev, cut, [])
        table[key].client_ids.append(cid)
    return [table[k] for k in sorted(table.keys(), key=str)]


def bucket_size(n: int) -> int:
    """Round a group/cohort size up to the next power of two (>= 1).

    Shared by every consumer that pads a ragged client axis to a small
    set of compiled shapes — the SplitProgram serving executor and the
    chunk-streamed federation round — so a churning population lands on
    the same bucket (and the same compiled program) as long as its size
    stays within the bucket.
    """
    if n < 0:
        raise ValueError(f"bucket_size of negative count {n}")
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def head_layers(cut_pair: Tuple[int, int]) -> range:
    return range(0, cut_pair[0])


def server_layers(cut_pair: Tuple[int, int]) -> range:
    return range(cut_pair[0], cut_pair[1])


def tail_layers(cut_pair: Tuple[int, int], n_layers: int) -> range:
    return range(cut_pair[1], n_layers)


def client_owned_layers(cut_pair: Tuple[int, int], n_layers: int) -> List[int]:
    return list(head_layers(cut_pair)) + list(tail_layers(cut_pair, n_layers))


def server_union_span(groups: Sequence[ProfileGroup], net: str,
                      n_layers: int) -> List[int]:
    """All layer indices any client delegates to the server for net G|D."""
    owned = set()
    for g in groups:
        pair = (g.cut.g_h, g.cut.g_t) if net == "G" else (g.cut.d_h, g.cut.d_t)
        owned |= set(server_layers(pair))
    return sorted(owned)


def stack_params(init_fn, key, k: int, dtype=jnp.float32):
    """Initialize k independent copies of a layer, stacked on axis 0."""
    keys = jax.random.split(key, k)
    return jax.vmap(lambda kk: init_fn(kk, dtype))(keys)


def layer_pair(cut: Cut, net: str) -> Tuple[int, int]:
    return (cut.g_h, cut.g_t) if net == "G" else (cut.d_h, cut.d_t)
