"""Config-composable transformer LM covering all assigned families.

One block type per `ArchConfig.block_pattern` entry:
  attn / local_attn  — GQA + RoPE (+ sliding window), chunked flash-style
  rglru              — RecurrentGemma RG-LRU mixer
  mlstm / slstm      — xLSTM blocks
plus dense/MoE FFN, tied or untied vocab head, optional encoder-decoder
(whisper) and modality-frontend prefix embeddings (VLM/audio stubs).

Layers are grouped into super-blocks of `len(block_pattern)` and run
under `lax.scan` with `jax.checkpoint` per super-block so the lowered
HLO stays small for the 40-pair dry-run matrix and activation memory is
one residual per block.

Three entry points (lowered by launch/dryrun.py):
  * train_step   — forward+backward+Adam on [B, S] token batches
  * prefill      — build a KV/recurrent cache from [B, S] context
  * decode_step  — ONE token against the cache (decode_* input shapes)
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import nn
from repro.models.attention import (apply_rope, attn_init, chunked_attention,
                                    decode_attention, out_proj, qkv_proj)
from repro.models.moe import moe_apply, moe_init
from repro.models import recurrent as rec
from repro.sharding.policy import maybe_shard

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# norms / mlp
# ---------------------------------------------------------------------------

def _norm_init(cfg: ArchConfig, dtype):
    return (nn.layernorm_init(cfg.d_model, dtype) if cfg.norm == "layernorm"
            else nn.rmsnorm_init(cfg.d_model, dtype))


def _norm_apply(cfg: ArchConfig, p, x):
    return (nn.layernorm_apply(p, x) if cfg.norm == "layernorm"
            else nn.rmsnorm_apply(p, x))


def mlp_init(key, cfg: ArchConfig, dtype) -> Params:
    ki, kg, ko = jax.random.split(key, 3)
    std = 1.0 / math.sqrt(cfg.d_model)
    p = {"wi": nn.normal_init(std)(ki, (cfg.d_model, cfg.d_ff), dtype),
         "wo": nn.normal_init(1.0 / math.sqrt(cfg.d_ff))(
             ko, (cfg.d_ff, cfg.d_model), dtype)}
    if cfg.mlp_variant in ("swiglu", "geglu"):
        p["wg"] = nn.normal_init(std)(kg, (cfg.d_model, cfg.d_ff), dtype)
    return p


def mlp_apply(cfg: ArchConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    hi = jnp.einsum("bsd,df->bsf", x, p["wi"],
                    preferred_element_type=jnp.float32)
    if cfg.mlp_variant in ("swiglu", "geglu"):
        hg = jnp.einsum("bsd,df->bsf", x, p["wg"],
                        preferred_element_type=jnp.float32)
        act = (jax.nn.silu(hg) if cfg.mlp_variant == "swiglu"
               else nn.gelu(hg)) * hi
    else:
        act = nn.gelu(hi)
    # NOTE: no f32 preferred type on the row-parallel (output) matmul —
    # its cross-shard partial sums all-reduce in the operand dtype
    # (bf16 on TPU halves the dominant collective; §Perf iteration 9).
    out = jnp.einsum("bsf,fd->bsd", act.astype(x.dtype), p["wo"])
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def init_block(key, cfg: ArchConfig, kind: str, *, cross: bool = False
               ) -> Params:
    dtype = cfg.dtype
    keys = jax.random.split(key, 6)
    p: Params = {"norm1": _norm_init(cfg, dtype)}
    hd = cfg.resolved_head_dim
    if kind in ("attn", "local_attn"):
        p["attn"] = attn_init(keys[0], cfg.d_model, cfg.n_heads,
                              cfg.n_kv_heads, hd, qkv_bias=cfg.qkv_bias,
                              dtype=dtype)
    elif kind == "rglru":
        p["rglru"] = rec.rglru_init(keys[0], cfg.d_model,
                                    cfg.d_rnn or cfg.d_model, dtype=dtype)
    elif kind == "mlstm":
        p["mlstm"] = rec.mlstm_init(keys[0], cfg.d_model, cfg.n_heads, hd,
                                    dtype=dtype)
    elif kind == "slstm":
        p["slstm"] = rec.slstm_init(keys[0], cfg.d_model,
                                    cfg.d_rnn or cfg.d_model, dtype=dtype)
    else:
        raise ValueError(kind)
    if cross:
        p["norm_x"] = _norm_init(cfg, dtype)
        p["xattn"] = attn_init(keys[1], cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, hd, dtype=dtype)
    if cfg.d_ff > 0:
        p["norm2"] = _norm_init(cfg, dtype)
        if cfg.n_experts:
            p["moe"] = moe_init(keys[2], cfg.d_model, cfg.d_ff,
                                cfg.n_experts, mlp_variant=cfg.mlp_variant,
                                dtype=dtype)
        else:
            p["mlp"] = mlp_init(keys[2], cfg, dtype)
    return p


def _window_for(cfg: ArchConfig, kind: str,
                force_window: Optional[int]) -> Optional[int]:
    if force_window is not None:
        return force_window
    if kind == "local_attn":
        return cfg.local_window
    return cfg.sliding_window


def block_seq(cfg: ArchConfig, kind: str, p: Params, x: jnp.ndarray,
              positions: jnp.ndarray, *, causal: bool = True,
              enc_out: Optional[jnp.ndarray] = None,
              force_window: Optional[int] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training/prefill forward. Returns (x, moe_aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = _norm_apply(cfg, p["norm1"], x)
    h = maybe_shard(h, "resid_inner")
    if kind in ("attn", "local_attn"):
        q, k, v = qkv_proj(p["attn"], h)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = chunked_attention(q, k, v, window=_window_for(cfg, kind,
                                                          force_window),
                              causal=causal)
        x = x + out_proj(p["attn"], o)
    elif kind == "rglru":
        o, _ = rec.rglru_seq(p["rglru"], h)
        x = x + o
    elif kind == "mlstm":
        o, _ = rec.mlstm_seq(p["mlstm"], h)
        x = x + o
    elif kind == "slstm":
        o, _ = rec.slstm_seq(p["slstm"], h)
        x = x + o
    if enc_out is not None and "xattn" in p:
        hx = _norm_apply(cfg, p["norm_x"], x)
        q, _, _ = qkv_proj(p["xattn"], hx)
        _, k, v = qkv_proj(p["xattn"], enc_out)
        o = chunked_attention(q, k, v, causal=False)
        x = x + out_proj(p["xattn"], o)
    if cfg.d_ff > 0:
        h2 = _norm_apply(cfg, p["norm2"], x)
        h2 = maybe_shard(h2, "resid_inner")
        if cfg.n_experts:
            o, moe_aux = moe_apply(p["moe"], h2, top_k=cfg.moe_top_k,
                                   capacity_factor=cfg.capacity_factor,
                                   mlp_variant=cfg.mlp_variant)
            aux = aux + moe_aux["load_balance"] + 1e-3 * moe_aux["router_z"]
        else:
            o = mlp_apply(cfg, p["mlp"], h2)
        x = x + o
    x = maybe_shard(x, "resid")
    return x, aux


# --- cache handling --------------------------------------------------------

def _attn_cache_len(cfg: ArchConfig, kind: str, ctx_len: int,
                    margin: int, force_window: Optional[int]) -> int:
    w = _window_for(cfg, kind, force_window)
    if w is not None:
        return min(ctx_len + margin, w)
    return ctx_len + margin


def init_cache_entry(cfg: ArchConfig, kind: str, batch: int, ctx_len: int,
                     *, margin: int = 16,
                     force_window: Optional[int] = None) -> Params:
    hd = cfg.resolved_head_dim
    dt = cfg.dtype
    if kind in ("attn", "local_attn"):
        s = _attn_cache_len(cfg, kind, ctx_len, margin, force_window)
        return {"k": jnp.zeros((batch, s, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((batch, s, cfg.n_kv_heads, hd), dt)}
    if kind == "rglru":
        return {"h": jnp.zeros((batch, cfg.d_rnn or cfg.d_model), jnp.float32)}
    if kind == "mlstm":
        return {"C": jnp.zeros((batch, cfg.n_heads, hd, hd), jnp.float32),
                "n": jnp.zeros((batch, cfg.n_heads, hd), jnp.float32)}
    if kind == "slstm":
        return {"c": jnp.zeros((batch, cfg.d_rnn or cfg.d_model), jnp.float32),
                "n": jnp.zeros((batch, cfg.d_rnn or cfg.d_model), jnp.float32),
                "m": jnp.full((batch, cfg.d_rnn or cfg.d_model), -1e30,
                              jnp.float32)}
    raise ValueError(kind)


def block_prefill(cfg: ArchConfig, kind: str, p: Params, x: jnp.ndarray,
                  positions: jnp.ndarray, ctx_len: int, *,
                  enc_out: Optional[jnp.ndarray] = None, margin: int = 16,
                  force_window: Optional[int] = None
                  ) -> Tuple[jnp.ndarray, Params]:
    """Forward + produce the block's cache entry."""
    B, S = x.shape[0], x.shape[1]
    h = _norm_apply(cfg, p["norm1"], x)
    if kind in ("attn", "local_attn"):
        q, k, v = qkv_proj(p["attn"], h)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = chunked_attention(q, k, v,
                              window=_window_for(cfg, kind, force_window))
        x = x + out_proj(p["attn"], o)
        s_cache = _attn_cache_len(cfg, kind, ctx_len, margin, force_window)
        keep = min(S, s_cache)
        entry = init_cache_entry(cfg, kind, B, ctx_len, margin=margin,
                                 force_window=force_window)
        k_keep = k[:, S - keep:].astype(entry["k"].dtype)
        v_keep = v[:, S - keep:].astype(entry["v"].dtype)
        if keep == s_cache and S % s_cache != 0:
            # ring discipline: token t lives at slot t % s_cache, so the
            # kept window [S-keep, S) starts at slot (S-keep) % s_cache
            shift = (S - keep) % s_cache
            k_keep = jnp.roll(k_keep, shift, axis=1)
            v_keep = jnp.roll(v_keep, shift, axis=1)
        entry["k"] = lax.dynamic_update_slice(entry["k"], k_keep, (0, 0, 0, 0))
        entry["v"] = lax.dynamic_update_slice(entry["v"], v_keep, (0, 0, 0, 0))
    elif kind == "rglru":
        o, hstate = rec.rglru_seq(p["rglru"], h)
        x = x + o
        entry = {"h": hstate}
    elif kind == "mlstm":
        o, st = rec.mlstm_seq(p["mlstm"], h)
        x = x + o
        entry = st
    elif kind == "slstm":
        o, st = rec.slstm_seq(p["slstm"], h)
        x = x + o
        entry = st
    if enc_out is not None and "xattn" in p:
        hx = _norm_apply(cfg, p["norm_x"], x)
        q, _, _ = qkv_proj(p["xattn"], hx)
        _, kx, vx = qkv_proj(p["xattn"], enc_out)
        o = chunked_attention(q, kx, vx, causal=False)
        x = x + out_proj(p["xattn"], o)
        entry["xk"] = kx.astype(cfg.dtype)
        entry["xv"] = vx.astype(cfg.dtype)
    if cfg.d_ff > 0:
        h2 = _norm_apply(cfg, p["norm2"], x)
        if cfg.n_experts:
            o, _ = moe_apply(p["moe"], h2, top_k=cfg.moe_top_k,
                             capacity_factor=cfg.capacity_factor,
                             mlp_variant=cfg.mlp_variant)
        else:
            o = mlp_apply(cfg, p["mlp"], h2)
        x = x + o
    x = maybe_shard(x, "resid")
    entry = {k_: maybe_shard(v_, "cache") if v_.ndim == 4 else v_
             for k_, v_ in entry.items()}
    return x, entry


def block_decode(cfg: ArchConfig, kind: str, p: Params, x: jnp.ndarray,
                 entry: Params, length: jnp.ndarray, *,
                 force_window: Optional[int] = None
                 ) -> Tuple[jnp.ndarray, Params]:
    """One-token step. x [B,1,D]; `length` tokens already in cache."""
    new_entry = dict(entry)
    h = _norm_apply(cfg, p["norm1"], x)
    if kind in ("attn", "local_attn"):
        q, k, v = qkv_proj(p["attn"], h)
        q = apply_rope(q, length[None] if length.ndim == 0 else length,
                       cfg.rope_theta)
        k = apply_rope(k, length[None] if length.ndim == 0 else length,
                       cfg.rope_theta)
        s_max = entry["k"].shape[1]
        idx = length % s_max
        kc = lax.dynamic_update_slice(entry["k"], k.astype(entry["k"].dtype),
                                      (0, idx, 0, 0))
        vc = lax.dynamic_update_slice(entry["v"], v.astype(entry["v"].dtype),
                                      (0, idx, 0, 0))
        new_entry["k"], new_entry["v"] = kc, vc
        valid = jnp.minimum(length + 1, s_max)
        o = decode_attention(q, kc, vc, valid)
        x = x + out_proj(p["attn"], o)
    elif kind == "rglru":
        o, hs = rec.rglru_step(p["rglru"], h, entry["h"])
        x = x + o
        new_entry["h"] = hs
    elif kind == "mlstm":
        o, st = rec.mlstm_step(p["mlstm"], h, {"C": entry["C"],
                                               "n": entry["n"]})
        x = x + o
        new_entry.update(st)
    elif kind == "slstm":
        o, st = rec.slstm_step(p["slstm"], h, {"c": entry["c"],
                                               "n": entry["n"],
                                               "m": entry["m"]})
        x = x + o
        new_entry.update(st)
    if "xk" in entry and "xattn" in p:
        hx = _norm_apply(cfg, p["norm_x"], x)
        q, _, _ = qkv_proj(p["xattn"], hx)
        enc_len = jnp.asarray(entry["xk"].shape[1], jnp.int32)
        o = decode_attention(q, entry["xk"], entry["xv"], enc_len)
        x = x + out_proj(p["xattn"], o)
    if cfg.d_ff > 0:
        h2 = _norm_apply(cfg, p["norm2"], x)
        if cfg.n_experts:
            o, _ = moe_apply(p["moe"], h2, top_k=cfg.moe_top_k,
                             capacity_factor=cfg.capacity_factor,
                             mlp_variant=cfg.mlp_variant)
        else:
            o = mlp_apply(cfg, p["mlp"], h2)
        x = x + o
    return x, new_entry


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def _pattern_split(cfg: ArchConfig) -> Tuple[int, Tuple[str, ...]]:
    pat = cfg.block_pattern
    n_super = cfg.n_layers // len(pat)
    rem = cfg.n_layers - n_super * len(pat)
    rest = tuple(pat[i] for i in range(rem))
    return n_super, rest


def init_lm(key, cfg: ArchConfig) -> Params:
    dtype = cfg.dtype
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": nn.embedding_init(keys[0], cfg.vocab, cfg.d_model,
                                   dtype=dtype),
        "final_norm": _norm_init(cfg, dtype),
    }
    cross = cfg.is_encoder_decoder
    n_super, rest = _pattern_split(cfg)
    blocks = {}
    for j, kind in enumerate(cfg.block_pattern):
        sub = jax.random.split(keys[1], n_super * (j + 1))[-n_super:]
        blocks[f"p{j}_{kind}"] = jax.vmap(
            lambda kk: init_block(kk, cfg, kind, cross=cross))(sub)
    params["blocks"] = blocks
    params["rest"] = {
        f"r{i}_{kind}": init_block(jax.random.fold_in(keys[2], i), cfg, kind,
                                   cross=cross)
        for i, kind in enumerate(rest)}
    if not cfg.tie_embeddings:
        params["lm_head"] = nn.dense_init(keys[3], cfg.d_model, cfg.vocab,
                                          use_bias=False, dtype=dtype)
    if cfg.is_encoder_decoder:
        enc_keys = jax.random.split(keys[4], cfg.n_enc_layers)
        params["enc_blocks"] = jax.vmap(
            lambda kk: init_block(kk, cfg, "attn"))(enc_keys)
        params["enc_norm"] = _norm_init(cfg, dtype)
    return params


def _embed(cfg: ArchConfig, params: Params, tokens: jnp.ndarray,
           prefix_embeds: Optional[jnp.ndarray]) -> jnp.ndarray:
    x = nn.embedding_apply(params["embed"], tokens).astype(cfg.dtype)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.dtype), x], axis=1)
    return maybe_shard(x, "resid")


def _logits(cfg: ArchConfig, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    x = _norm_apply(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = nn.embedding_attend(params["embed"], x)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]["w"],
                            preferred_element_type=jnp.float32)
    return maybe_shard(logits, "logits")


def _encoder(cfg: ArchConfig, params: Params,
             frames: jnp.ndarray, unroll: int = 1) -> jnp.ndarray:
    """Whisper encoder over stubbed frame embeddings [B, S_enc, D]."""
    x = frames.astype(cfg.dtype)
    pos = jnp.arange(x.shape[1])

    def body(x, p):
        x, _ = block_seq(cfg, "attn", p, x, pos, causal=False)
        return x, None

    body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["enc_blocks"], unroll=unroll)
    return _norm_apply(cfg, params["enc_norm"], x)


def forward_train(cfg: ArchConfig, params: Params, tokens: jnp.ndarray,
                  prefix_embeds: Optional[jnp.ndarray] = None,
                  enc_frames: Optional[jnp.ndarray] = None,
                  force_window: Optional[int] = None,
                  unroll: int = 1
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits [B, S_total, V], moe_aux)."""
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encoder(cfg, params, enc_frames, unroll=unroll)
    x = _embed(cfg, params, tokens, prefix_embeds)
    S = x.shape[1]
    positions = jnp.arange(S)
    pattern = cfg.block_pattern

    def superblock(x, slice_p):
        aux = jnp.zeros((), jnp.float32)
        for j, kind in enumerate(pattern):
            x, a = block_seq(cfg, kind, slice_p[f"p{j}_{kind}"], x, positions,
                             enc_out=enc_out, force_window=force_window)
            aux = aux + a
        return x, aux

    def body(carry, slice_p):
        x, aux = carry
        x, a = jax.checkpoint(superblock)(x, slice_p)
        return (x, aux + a), None

    (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                           params["blocks"], unroll=unroll)
    n_super, rest = _pattern_split(cfg)
    for i, kind in enumerate(rest):
        x, a = block_seq(cfg, kind, params["rest"][f"r{i}_{kind}"], x,
                         positions, enc_out=enc_out,
                         force_window=force_window)
        aux = aux + a
    return _logits(cfg, params, x), aux


def lm_loss(cfg: ArchConfig, params: Params, batch: Dict[str, jnp.ndarray],
            force_window: Optional[int] = None,
            unroll: int = 1) -> Tuple[jnp.ndarray, Dict]:
    logits, aux = forward_train(
        cfg, params, batch["tokens"],
        prefix_embeds=batch.get("prefix_embeds"),
        enc_frames=batch.get("enc_frames"),
        force_window=force_window, unroll=unroll)
    labels = batch["labels"]
    # align: labels cover the *text* region (suffix) only
    S_lab = labels.shape[1]
    logits_txt = logits[:, -S_lab:]
    logp = jax.nn.log_softmax(logits_txt.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        loss = jnp.mean(nll)
    else:
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + 1e-2 * aux
    return total, {"nll": loss, "moe_aux": aux}


def make_train_step(cfg: ArchConfig, optimizer,
                    force_window: Optional[int] = None, unroll: int = 1):
    opt_init, opt_update = optimizer

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch, force_window, unroll), has_aux=True
        )(params)
        opt_state, params = opt_update(opt_state, grads, params)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step, opt_init


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, ctx_len: int, *,
               margin: int = 16, force_window: Optional[int] = None) -> Params:
    n_super, rest = _pattern_split(cfg)
    scanned = {}
    for j, kind in enumerate(cfg.block_pattern):
        one = init_cache_entry(cfg, kind, batch, ctx_len, margin=margin,
                               force_window=force_window)
        scanned[f"p{j}_{kind}"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n_super,) + x.shape), one)
        if cfg.is_encoder_decoder:
            hd = cfg.resolved_head_dim
            xkv = jnp.zeros((n_super, batch, cfg.num_prefix_embeds,
                             cfg.n_kv_heads, hd), cfg.dtype)
            scanned[f"p{j}_{kind}"]["xk"] = xkv
            scanned[f"p{j}_{kind}"]["xv"] = xkv
    rest_cache = {}
    for i, kind in enumerate(rest):
        rest_cache[f"r{i}_{kind}"] = init_cache_entry(
            cfg, kind, batch, ctx_len, margin=margin,
            force_window=force_window)
    return {"scanned": scanned, "rest": rest_cache,
            "length": jnp.zeros((), jnp.int32)}


def prefill(cfg: ArchConfig, params: Params, tokens: jnp.ndarray,
            prefix_embeds: Optional[jnp.ndarray] = None,
            enc_frames: Optional[jnp.ndarray] = None, *,
            margin: int = 16, force_window: Optional[int] = None,
            unroll: int = 1) -> Tuple[jnp.ndarray, Params]:
    """Returns (last-position logits [B, V], cache)."""
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encoder(cfg, params, enc_frames, unroll=unroll)
    x = _embed(cfg, params, tokens, prefix_embeds)
    S = x.shape[1]
    positions = jnp.arange(S)
    pattern = cfg.block_pattern

    def body(x, slice_p):
        entries = {}
        for j, kind in enumerate(pattern):
            x, e = block_prefill(cfg, kind, slice_p[f"p{j}_{kind}"], x,
                                 positions, S, enc_out=enc_out,
                                 margin=margin, force_window=force_window)
            entries[f"p{j}_{kind}"] = e
        return x, entries

    x, scanned = lax.scan(body, x, params["blocks"], unroll=unroll)
    n_super, rest = _pattern_split(cfg)
    rest_cache = {}
    for i, kind in enumerate(rest):
        x, e = block_prefill(cfg, kind, params["rest"][f"r{i}_{kind}"], x,
                             positions, S, enc_out=enc_out, margin=margin,
                             force_window=force_window)
        rest_cache[f"r{i}_{kind}"] = e
    logits = _logits(cfg, params, x[:, -1:])
    cache = {"scanned": scanned, "rest": rest_cache,
             "length": jnp.asarray(S, jnp.int32)}
    return logits[:, 0], cache


def decode_step(cfg: ArchConfig, params: Params, token: jnp.ndarray,
                cache: Params, *, force_window: Optional[int] = None,
                unroll: int = 1) -> Tuple[jnp.ndarray, Params]:
    """token [B] or [B,1] -> (logits [B, V], new cache). ONE new token."""
    if token.ndim == 1:
        token = token[:, None]
    x = nn.embedding_apply(params["embed"], token).astype(cfg.dtype)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    length = cache["length"]
    pattern = cfg.block_pattern

    def body(x, inp):
        slice_p, slice_c = inp
        new_c = {}
        for j, kind in enumerate(pattern):
            x, e = block_decode(cfg, kind, slice_p[f"p{j}_{kind}"], x,
                                slice_c[f"p{j}_{kind}"], length,
                                force_window=force_window)
            new_c[f"p{j}_{kind}"] = e
        return x, new_c

    x, new_scanned = lax.scan(body, x, (params["blocks"], cache["scanned"]),
                              unroll=unroll)
    n_super, rest = _pattern_split(cfg)
    new_rest = {}
    for i, kind in enumerate(rest):
        x, e = block_decode(cfg, kind, params["rest"][f"r{i}_{kind}"], x,
                            cache["rest"][f"r{i}_{kind}"], length,
                            force_window=force_window)
        new_rest[f"r{i}_{kind}"] = e
    logits = _logits(cfg, params, x)
    return logits[:, 0], {"scanned": new_scanned, "rest": new_rest,
                          "length": length + 1}
