"""Recurrent / SSM blocks: RG-LRU (RecurrentGemma), mLSTM and sLSTM
(xLSTM). All expose a parallel `*_seq` form for training (associative
scan or lax.scan over time) and a single-step `*_step` form for decode
with O(1) state — this is what makes long_500k feasible for these
families.

TPU adaptation note (DESIGN.md §3): the original CUDA kernels fuse the
recurrence into one thread-block scan; on TPU we express RG-LRU/mLSTM as
`lax.associative_scan` over the sequence axis (log-depth, maps to VPU)
and keep the heavy projections as MXU matmuls.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import nn


# ---------------------------------------------------------------------------
# RG-LRU (Real-Gated Linear Recurrent Unit) — arXiv:2402.19427
#   h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
#   a_t = exp(-c * softplus(Lambda) * sigmoid(r_t))
# ---------------------------------------------------------------------------

RG_LRU_C = 8.0


def rglru_init(key, d_model: int, d_rnn: int, dtype=jnp.float32) -> Dict:
    """Note: we omit Griffin's width-4 temporal conv before the LRU (a
    minor smoothing term); the gated linear recurrence — the block's
    contribution — is implemented exactly. Recorded in DESIGN.md."""
    k1, k2, k4, k5, k6 = jax.random.split(key, 5)
    std = 1.0 / math.sqrt(d_model)
    # lambda init so the recurrence decay a^(1/c) lands in [0.9, 0.999)
    u = jax.random.uniform(k4, (d_rnn,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / RG_LRU_C))  # inverse softplus
    return {
        "w_in": nn.normal_init(std)(k1, (d_model, d_rnn), dtype),
        "w_gate_x": nn.normal_init(std)(k2, (d_model, d_rnn), dtype),
        "lambda": lam,
        "w_rec_gate": nn.normal_init(1.0 / math.sqrt(d_rnn))(
            k5, (d_rnn, d_rnn), dtype),
        "w_in_gate": nn.normal_init(1.0 / math.sqrt(d_rnn))(
            k6, (d_rnn, d_rnn), dtype),
        "w_out": nn.normal_init(1.0 / math.sqrt(d_rnn))(
            jax.random.fold_in(key, 7), (d_rnn, d_model), dtype),
    }


def _rglru_gates(p: Dict, u: jnp.ndarray):
    """u [.., S, d_rnn] -> (a, gated_input) in f32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("...sd,de->...se", uf,
                                  p["w_rec_gate"].astype(jnp.float32)))
    i = jax.nn.sigmoid(jnp.einsum("...sd,de->...se", uf,
                                  p["w_in_gate"].astype(jnp.float32)))
    log_a = -RG_LRU_C * jax.nn.softplus(p["lambda"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i * uf)
    return a, gated


def rglru_seq(p: Dict, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, S, D] -> (out [B, S, D], final_state [B, d_rnn])."""
    u = jnp.einsum("bsd,de->bse", x, p["w_in"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    a, gated = _rglru_gates(p, u)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_sc, h = lax.associative_scan(combine, (a, gated), axis=1)
    gate_x = jax.nn.sigmoid(jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), p["w_gate_x"].astype(jnp.float32)))
    out = jnp.einsum("bse,ed->bsd", (h * gate_x).astype(x.dtype), p["w_out"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, h[:, -1].astype(jnp.float32)


def rglru_step(p: Dict, x: jnp.ndarray, state: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, 1, D]; state [B, d_rnn] -> (out [B,1,D], new_state)."""
    u = jnp.einsum("bsd,de->bse", x, p["w_in"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    a, gated = _rglru_gates(p, u)
    h = a[:, 0] * state + gated[:, 0]
    gate_x = jax.nn.sigmoid(jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), p["w_gate_x"].astype(jnp.float32)))
    out = jnp.einsum("be,ed->bd", (h * gate_x[:, 0]).astype(x.dtype),
                     p["w_out"], preferred_element_type=jnp.float32)
    return out[:, None].astype(x.dtype), h


# ---------------------------------------------------------------------------
# mLSTM (matrix-memory LSTM) — arXiv:2405.04517
#   C_t = f_t C_{t-1} + i_t (v_t k_t^T);  n_t = f_t n_{t-1} + i_t k_t
#   h_t = o_t * (C_t q_t) / max(|n_t^T q_t|, 1)
# ---------------------------------------------------------------------------

def mlstm_init(key, d_model: int, n_heads: int, head_dim: int,
               dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 6)
    std = 1.0 / math.sqrt(d_model)
    return {
        "wq": nn.normal_init(std)(ks[0], (d_model, n_heads, head_dim), dtype),
        "wk": nn.normal_init(std)(ks[1], (d_model, n_heads, head_dim), dtype),
        "wv": nn.normal_init(std)(ks[2], (d_model, n_heads, head_dim), dtype),
        "w_if": nn.normal_init(std)(ks[3], (d_model, n_heads, 2), dtype),
        "w_o": nn.normal_init(std)(ks[4], (d_model, n_heads, head_dim), dtype),
        "wo": nn.normal_init(1.0 / math.sqrt(n_heads * head_dim))(
            ks[5], (n_heads, head_dim, d_model), dtype),
    }


def _mlstm_qkvg(p, x):
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"],
                   preferred_element_type=jnp.float32)
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"],
                   preferred_element_type=jnp.float32)
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"],
                   preferred_element_type=jnp.float32)
    if_ = jnp.einsum("bsd,dnt->bsnt", x.astype(jnp.float32),
                     p["w_if"].astype(jnp.float32))
    i_gate = jnp.exp(jnp.clip(if_[..., 0], -10.0, 10.0))   # exp input gate
    f_gate = jax.nn.sigmoid(if_[..., 1] + 1.0)
    o_gate = jax.nn.sigmoid(jnp.einsum(
        "bsd,dnh->bsnh", x.astype(jnp.float32), p["w_o"].astype(jnp.float32)))
    hd = q.shape[-1]
    k = k / math.sqrt(hd)
    return q, k, v, i_gate, f_gate, o_gate


def mlstm_seq(p: Dict, x: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    """Chunkwise-parallel mLSTM via lax.scan over time (clear, O(S) mem).
    x [B,S,D] -> (out [B,S,D], state {C [B,N,hd,hd], n [B,N,hd]})."""
    q, k, v, i_g, f_g, o_g = _mlstm_qkvg(p, x)
    B, S, N, hd = q.shape

    def step(carry, t):
        C, n = carry
        it, ft = i_g[:, t], f_g[:, t]                       # [B,N]
        kv = jnp.einsum("bnh,bng->bnhg", k[:, t], v[:, t])  # [B,N,hd,hd]
        C = ft[..., None, None] * C + it[..., None, None] * kv
        n = ft[..., None] * n + it[..., None] * k[:, t]
        num = jnp.einsum("bnhg,bnh->bng", C, q[:, t])
        den = jnp.maximum(jnp.abs(jnp.einsum("bnh,bnh->bn", n, q[:, t])), 1.0)
        h = o_g[:, t] * num / den[..., None]
        return (C, n), h

    C0 = jnp.zeros((B, N, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, N, hd), jnp.float32)
    (C, n), hs = lax.scan(step, (C0, n0), jnp.arange(S))
    hs = jnp.moveaxis(hs, 0, 1)  # [B,S,N,hd]
    out = jnp.einsum("bsnh,nhd->bsd", hs.astype(x.dtype), p["wo"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, {"C": C, "n": n}


def mlstm_step(p: Dict, x: jnp.ndarray, state: Dict
               ) -> Tuple[jnp.ndarray, Dict]:
    """x [B,1,D] -> (out [B,1,D], new state)."""
    q, k, v, i_g, f_g, o_g = _mlstm_qkvg(p, x)
    C, n = state["C"], state["n"]
    it, ft = i_g[:, 0], f_g[:, 0]
    kv = jnp.einsum("bnh,bng->bnhg", k[:, 0], v[:, 0])
    C = ft[..., None, None] * C + it[..., None, None] * kv
    n = ft[..., None] * n + it[..., None] * k[:, 0]
    num = jnp.einsum("bnhg,bnh->bng", C, q[:, 0])
    den = jnp.maximum(jnp.abs(jnp.einsum("bnh,bnh->bn", n, q[:, 0])), 1.0)
    h = o_g[:, 0] * num / den[..., None]
    out = jnp.einsum("bnh,nhd->bd", h.astype(x.dtype), p["wo"],
                     preferred_element_type=jnp.float32)
    return out[:, None].astype(x.dtype), {"C": C, "n": n}


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory LSTM with exponential gating) — arXiv:2405.04517
# ---------------------------------------------------------------------------

def slstm_init(key, d_model: int, d_hidden: int, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 5)
    std = 1.0 / math.sqrt(d_model)
    return {
        "w_z": nn.normal_init(std)(ks[0], (d_model, d_hidden), dtype),
        "w_i": nn.normal_init(std)(ks[1], (d_model, d_hidden), dtype),
        "w_f": nn.normal_init(std)(ks[2], (d_model, d_hidden), dtype),
        "w_o": nn.normal_init(std)(ks[3], (d_model, d_hidden), dtype),
        "w_out": nn.normal_init(1.0 / math.sqrt(d_hidden))(
            ks[4], (d_hidden, d_model), dtype),
    }


def _slstm_pre(p, x):
    xf = x.astype(jnp.float32)
    z = jnp.tanh(jnp.einsum("bsd,dh->bsh", xf, p["w_z"].astype(jnp.float32)))
    i = jnp.clip(jnp.einsum("bsd,dh->bsh", xf, p["w_i"].astype(jnp.float32)),
                 -10, 10)
    f = jnp.clip(jnp.einsum("bsd,dh->bsh", xf, p["w_f"].astype(jnp.float32)),
                 -10, 10)
    o = jax.nn.sigmoid(jnp.einsum("bsd,dh->bsh", xf,
                                  p["w_o"].astype(jnp.float32)))
    return z, i, f, o


def _slstm_cell(c, n, m, z_t, i_t, f_t, o_t):
    """Stabilized exponential-gating cell update (eq. 15-19 of xLSTM)."""
    log_f = jax.nn.log_sigmoid(f_t)
    new_m = jnp.maximum(log_f + m, i_t)
    i_s = jnp.exp(i_t - new_m)
    f_s = jnp.exp(log_f + m - new_m)
    c = f_s * c + i_s * z_t
    n = f_s * n + i_s
    h = o_t * c / jnp.maximum(n, 1e-6)
    return c, n, new_m, h


def slstm_seq(p: Dict, x: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    z, i, f, o = _slstm_pre(p, x)
    B, S, H = z.shape

    def step(carry, t):
        c, n, m = carry
        c, n, m, h = _slstm_cell(c, n, m, z[:, t], i[:, t], f[:, t], o[:, t])
        return (c, n, m), h

    c0 = jnp.zeros((B, H), jnp.float32)
    n0 = jnp.zeros((B, H), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    (c, n, m), hs = lax.scan(step, (c0, n0, m0), jnp.arange(S))
    hs = jnp.moveaxis(hs, 0, 1)
    out = jnp.einsum("bsh,hd->bsd", hs.astype(x.dtype), p["w_out"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, {"c": c, "n": n, "m": m}


def slstm_step(p: Dict, x: jnp.ndarray, state: Dict
               ) -> Tuple[jnp.ndarray, Dict]:
    z, i, f, o = _slstm_pre(p, x)
    c, n, m, h = _slstm_cell(state["c"], state["n"], state["m"],
                             z[:, 0], i[:, 0], f[:, 0], o[:, 0])
    out = jnp.einsum("bh,hd->bd", h.astype(x.dtype), p["w_out"],
                     preferred_element_type=jnp.float32)
    return out[:, None].astype(x.dtype), {"c": c, "n": n, "m": m}
