"""Attention substrate: RoPE, GQA, chunked (flash-style) training
attention, sliding windows, and KV-cache decode.

Training attention is *chunked* with an online-softmax accumulator
(`lax.scan` over KV chunks per query chunk) so activation memory is
O(S * chunk) instead of O(S^2) — mandatory for prefill_32k and the big
dry-run shapes. Pure JAX and differentiable; the TPU Pallas twin of the
decode path lives in repro/kernels/flash_decode.py.

Shapes: x [B, S, D]; q [B, S, H, hd]; k/v [B, S, KV, hd].
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import nn

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x [B, S, N, hd]; positions [B, S] (or [S])."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B,S,hd/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------

def attn_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int, *,
              qkv_bias: bool = False, dtype=jnp.float32) -> Dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d_model)
    p = {
        "wq": nn.normal_init(std)(kq, (d_model, n_heads, head_dim), dtype),
        "wk": nn.normal_init(std)(kk, (d_model, n_kv, head_dim), dtype),
        "wv": nn.normal_init(std)(kv, (d_model, n_kv, head_dim), dtype),
        "wo": nn.normal_init(std / math.sqrt(2.0))(
            ko, (n_heads, head_dim, d_model), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads, head_dim), dtype)
        p["bk"] = jnp.zeros((n_kv, head_dim), dtype)
        p["bv"] = jnp.zeros((n_kv, head_dim), dtype)
    return p


def qkv_proj(p: Dict, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def out_proj(p: Dict, o: jnp.ndarray) -> jnp.ndarray:
    # row-parallel output matmul: partial sums all-reduce in operand
    # dtype (bf16) — see §Perf iteration 9
    return jnp.einsum("bsnh,nhd->bsd", o, p["wo"].astype(o.dtype))


# ---------------------------------------------------------------------------
# chunked causal attention (training / prefill)
# ---------------------------------------------------------------------------

def _chunk_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                window: Optional[int]) -> jnp.ndarray:
    """[Sq, Sk] True where attendable (causal + optional sliding window)."""
    m = q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      window: Optional[int] = None,
                      q_chunk: int = 512, k_chunk: int = 512,
                      causal: bool = True) -> jnp.ndarray:
    """Flash-style attention. q [B,S,H,hd], k/v [B,S,KV,hd] -> [B,S,H,hd].

    GQA via head grouping; online softmax over KV chunks.
    """
    B, S, H, hd = q.shape
    S_kv = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    q_chunk = min(q_chunk, S)
    k_chunk = min(k_chunk, S_kv)
    # pad both sequence axes to chunk multiples
    Sq = -(-S // q_chunk) * q_chunk
    Sk = -(-S_kv // k_chunk) * k_chunk
    qp = jnp.pad(q, ((0, 0), (0, Sq - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Sk - S_kv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sk - S_kv), (0, 0), (0, 0)))
    nq, nk = Sq // q_chunk, Sk // k_chunk

    # [B, nq, qc, KV, G, hd]
    qh = qp.reshape(B, nq, q_chunk, KV, G, hd)
    kh = kp.reshape(B, nk, k_chunk, KV, hd)
    vh = vp.reshape(B, nk, k_chunk, KV, hd)
    k_valid = (jnp.arange(Sk) < S_kv).reshape(nk, k_chunk)

    def per_q_chunk_impl(qi, q_blk, kh_b, vh_b):
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inp):
            acc, m_max, denom = carry
            kj, k_blk, v_blk, kvalid = inp
            k_pos = kj * k_chunk + jnp.arange(k_chunk)
            s = jnp.einsum("qkgh,ckh->qkgc", q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * scale
            if causal:
                mask = _chunk_mask(q_pos, k_pos, window)
            else:
                mask = jnp.ones((q_chunk, k_chunk), bool)
            mask = mask & kvalid[None, :]
            s = jnp.where(mask[:, None, None, :], s, NEG_INF)
            blk_max = jnp.max(s, axis=-1)
            new_max = jnp.maximum(m_max, blk_max)
            corr = jnp.exp(m_max - new_max)
            p = jnp.exp(s - new_max[..., None])
            acc = acc * corr[..., None] + jnp.einsum(
                "qkgc,ckh->qkgh", p, v_blk.astype(jnp.float32))
            denom = denom * corr + p.sum(-1)
            return (acc, new_max, denom), None

        acc0 = jnp.zeros((q_chunk, KV, G, hd), jnp.float32)
        max0 = jnp.full((q_chunk, KV, G), NEG_INF, jnp.float32)
        den0 = jnp.zeros((q_chunk, KV, G), jnp.float32)
        (acc, _, denom), _ = lax.scan(
            kv_step, (acc0, max0, den0),
            (jnp.arange(nk), kh_b, vh_b, k_valid))
        return acc / jnp.maximum(denom[..., None], 1e-30)

    def batch_fn(q_b, kh_b, vh_b):
        return jax.vmap(lambda qi, qb: per_q_chunk_impl(qi, qb, kh_b, vh_b))(
            jnp.arange(nq), q_b)

    out = jax.vmap(batch_fn)(qh, kh, vh)  # [B,nq,qc,KV,G,hd]
    out = out.reshape(B, Sq, H, hd)[:, :S]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# decode attention (one new token vs a cache)
# ---------------------------------------------------------------------------

def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, cache_len: jnp.ndarray
                     ) -> jnp.ndarray:
    """q [B,1,H,hd]; caches [B,S,KV,hd]; cache_len [] or [B].

    Full-softmax over the (masked) cache. The Pallas flash_decode kernel
    implements the same contraction blocked over S.
    """
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qh = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qh.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(S)
    if cache_len.ndim == 0:
        valid = pos[None, :] < cache_len
    else:
        valid = pos[None, :] < cache_len[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


@dataclasses.dataclass
class KVCache:
    """Ring-buffer KV cache (bounded by window for SWA archs)."""
    k: jnp.ndarray   # [B, S_max, KV, hd]
    v: jnp.ndarray
    length: jnp.ndarray  # [] int32 — logical tokens seen

    @staticmethod
    def zeros(batch: int, s_max: int, n_kv: int, head_dim: int,
              dtype=jnp.bfloat16) -> "KVCache":
        return KVCache(jnp.zeros((batch, s_max, n_kv, head_dim), dtype),
                       jnp.zeros((batch, s_max, n_kv, head_dim), dtype),
                       jnp.zeros((), jnp.int32))

    def append(self, k_new: jnp.ndarray, v_new: jnp.ndarray) -> "KVCache":
        """Append one token (k_new [B,1,KV,hd]) at ring position."""
        s_max = self.k.shape[1]
        idx = self.length % s_max
        k = lax.dynamic_update_slice(self.k, k_new.astype(self.k.dtype),
                                     (0, idx, 0, 0))
        v = lax.dynamic_update_slice(self.v, v_new.astype(self.v.dtype),
                                     (0, idx, 0, 0))
        return KVCache(k, v, self.length + 1)


jax.tree_util.register_pytree_node(
    KVCache,
    lambda c: ((c.k, c.v, c.length), None),
    lambda _, xs: KVCache(*xs))
