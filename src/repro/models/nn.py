"""Pure-JAX neural-network substrate.

No flax/haiku offline: parameters are plain pytrees (nested dicts of
jnp arrays); every module is an (init, apply) pair of pure functions.
Conventions:
  * init(key, ...) -> params dict
  * apply(params, x, ...) -> output (and possibly new state)
  * images are NHWC, convolution weights HWIO (XLA native layouts)
  * matmuls accumulate in f32 (`preferred_element_type`) so bf16 weights
    are MXU-friendly on TPU while staying accurate.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _fan_in_out(shape: Sequence[int]) -> Tuple[int, int]:
    if len(shape) == 1:
        return shape[0], shape[0]
    if len(shape) == 2:
        return shape[0], shape[1]
    # conv kernels HWIO / HWOI: receptive field * channels
    receptive = int(math.prod(shape[:-2]))
    return shape[-2] * receptive, shape[-1] * receptive


def glorot_uniform(key, shape, dtype=jnp.float32):
    fan_in, fan_out = _fan_in_out(shape)
    limit = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, -limit, limit)


def he_normal(key, shape, dtype=jnp.float32):
    fan_in, _ = _fan_in_out(shape)
    std = math.sqrt(2.0 / max(fan_in, 1))
    return jax.random.normal(key, shape, dtype) * jnp.asarray(std, dtype)


def normal_init(std: float = 0.02):
    def init(key, shape, dtype=jnp.float32):
        return jax.random.normal(key, shape, dtype) * jnp.asarray(std, dtype)
    return init


def truncated_normal(key, shape, std=0.02, dtype=jnp.float32):
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype) * jnp.asarray(std, dtype)


# ---------------------------------------------------------------------------
# dense / embedding
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, *, use_bias: bool = True,
               dtype=jnp.float32, init=glorot_uniform) -> Params:
    kw, _ = jax.random.split(key)
    p = {"w": init(kw, (in_dim, out_dim), dtype)}
    if use_bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = jnp.einsum("...i,io->...o", x, p["w"],
                   preferred_element_type=jnp.float32)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(x.dtype)


def embedding_init(key, vocab: int, dim: int, *, dtype=jnp.float32) -> Params:
    return {"table": normal_init(1.0 / math.sqrt(dim))(key, (vocab, dim), dtype)}


def embedding_apply(p: Params, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["table"], ids, axis=0)


def embedding_attend(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Tied output head: logits = x @ table.T"""
    return jnp.einsum("...d,vd->...v", x, p["table"],
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# conv / conv-transpose (NHWC, HWIO)
# ---------------------------------------------------------------------------

def conv2d_init(key, in_ch: int, out_ch: int, kernel: int, *,
                use_bias: bool = True, dtype=jnp.float32) -> Params:
    p = {"w": he_normal(key, (kernel, kernel, in_ch, out_ch), dtype)}
    if use_bias:
        p["b"] = jnp.zeros((out_ch,), dtype)
    return p


def conv2d_apply(p: Params, x: jnp.ndarray, *, stride: int = 1,
                 padding: str = "SAME") -> jnp.ndarray:
    y = lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(x.dtype)


def convT2d_init(key, in_ch: int, out_ch: int, kernel: int, *,
                 use_bias: bool = True, dtype=jnp.float32) -> Params:
    # transposed conv kernel stored HWIO with I=in, O=out
    p = {"w": he_normal(key, (kernel, kernel, in_ch, out_ch), dtype)}
    if use_bias:
        p["b"] = jnp.zeros((out_ch,), dtype)
    return p


def convT2d_apply(p: Params, x: jnp.ndarray, *, stride: int = 1,
                  padding: str = "SAME") -> jnp.ndarray:
    y = lax.conv_transpose(
        x, p["w"].astype(x.dtype), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(x.dtype)


def conv1d_init(key, in_ch: int, out_ch: int, kernel: int, *,
                use_bias: bool = True, dtype=jnp.float32) -> Params:
    p = {"w": he_normal(key, (kernel, in_ch, out_ch), dtype)}
    if use_bias:
        p["b"] = jnp.zeros((out_ch,), dtype)
    return p


def conv1d_apply(p: Params, x: jnp.ndarray, *, stride: int = 1,
                 padding: str = "SAME") -> jnp.ndarray:
    """x: [B, T, C]"""
    y = lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), (stride,), padding,
        dimension_numbers=("NTC", "TIO", "NTC"),
        preferred_element_type=jnp.float32)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------

def batchnorm_init(ch: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((ch,), dtype), "bias": jnp.zeros((ch,), dtype),
            "mean": jnp.zeros((ch,), jnp.float32), "var": jnp.ones((ch,), jnp.float32)}


def batchnorm_apply(p: Params, x: jnp.ndarray, *, train: bool,
                    momentum: float = 0.9, eps: float = 1e-5
                    ) -> Tuple[jnp.ndarray, Params]:
    """Returns (y, updated_params). Reduces over all axes but the last."""
    axes = tuple(range(x.ndim - 1))
    if train:
        mean = jnp.mean(x.astype(jnp.float32), axes)
        var = jnp.var(x.astype(jnp.float32), axes)
        new_p = dict(p)
        new_p["mean"] = momentum * p["mean"] + (1 - momentum) * mean
        new_p["var"] = momentum * p["var"] + (1 - momentum) * var
    else:
        mean, var = p["mean"], p["var"]
        new_p = p
    inv = lax.rsqrt(var + eps)
    y = (x.astype(jnp.float32) - mean) * inv * p["scale"].astype(jnp.float32) \
        + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype), new_p


def layernorm_init(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm_apply(p: Params, x: jnp.ndarray, *, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rmsnorm_init(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm_apply(p: Params, x: jnp.ndarray, *, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    y = xf * lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def geglu(x, gate):
    return gelu(gate) * x


def swiglu(x, gate):
    return jax.nn.silu(gate) * x


def leaky_relu(x, slope: float = 0.2):
    return jnp.where(x >= 0, x, slope * x)


# ---------------------------------------------------------------------------
# pytree helpers
# ---------------------------------------------------------------------------

def tree_size(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(x.size * x.dtype.itemsize) for x in jax.tree_util.tree_leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda x: x * s, tree)


def tree_weighted_sum(stacked, weights):
    """stacked: pytree with leading client axis K; weights: [K]."""
    def agg(x):
        w = weights.astype(jnp.float32)
        return jnp.einsum("k,k...->...", w, x.astype(jnp.float32)).astype(x.dtype)
    return jax.tree_util.tree_map(agg, stacked)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))
