"""Mixture-of-Experts FFN — GShard/Switch-style dispatch & combine.

Top-k routing with capacity limits, expressed as einsums over a dispatch
one-hot tensor so the whole thing is MXU matmuls and partitions cleanly:
experts shard over the mesh 'model' axis when `n_experts % model == 0`
(expert parallelism with all-to-all inserted by GSPMD), otherwise the
expert FFN dim shards over 'model' (tensor parallelism inside experts).

Aux losses: switch load-balance loss + router z-loss.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import nn


def moe_init(key, d_model: int, d_ff: int, n_experts: int, *,
             mlp_variant: str = "swiglu", dtype=jnp.float32) -> Dict:
    kr, ki, kg, ko = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d_model)
    p = {
        "router": nn.normal_init(std)(kr, (d_model, n_experts), jnp.float32),
        "wi": nn.normal_init(std)(ki, (n_experts, d_model, d_ff), dtype),
        "wo": nn.normal_init(1.0 / math.sqrt(d_ff))(
            ko, (n_experts, d_ff, d_model), dtype),
    }
    if mlp_variant in ("swiglu", "geglu"):
        p["wg"] = nn.normal_init(std)(kg, (n_experts, d_model, d_ff), dtype)
    return p


def moe_apply(p: Dict, x: jnp.ndarray, *, top_k: int,
              capacity_factor: float = 1.25,
              mlp_variant: str = "swiglu"
              ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x [B, S, D] -> (out [B, S, D], aux losses).

    GShard-style *grouped* dispatch: each batch row is a routing group
    with its own capacity C = cf * S * k / E, so dispatch/combine are
    [B, S, E, C] (shardable over the data axis) instead of a single
    [B*S, E, B*C] monolith — B x smaller, and each device only holds its
    own rows' dispatch tensors.
    """
    B, S, D = x.shape
    E = p["router"].shape[1]

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # [B, S, E]

    # --- top-k gating, renormalized over the chosen experts
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)  # [B, S, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = max(1, int(capacity_factor * S * top_k / E))

    # --- dispatch/combine per group, looping over the k slots
    combine = jnp.zeros((B, S, E, capacity), jnp.float32)
    dispatch = jnp.zeros((B, S, E, capacity), bool)
    counts = jnp.zeros((B, E), jnp.int32)   # per-group expert fill
    for slot in range(top_k):
        e = gate_idx[..., slot]                           # [B, S]
        onehot = jax.nn.one_hot(e, E, dtype=jnp.int32)    # [B, S, E]
        pos = jnp.cumsum(onehot, axis=1) - 1 + counts[:, None, :]
        pos_e = jnp.take_along_axis(pos, e[..., None], 2)[..., 0]  # [B, S]
        keep = pos_e < capacity
        counts = counts + onehot.sum(1)
        pos_oh = jax.nn.one_hot(pos_e, capacity, dtype=jnp.float32)
        contrib = (onehot.astype(jnp.float32)[..., None]
                   * pos_oh[..., None, :])                # [B, S, E, C]
        contrib = contrib * keep[..., None, None]
        dispatch = dispatch | (contrib > 0)
        combine = combine + contrib * gate_vals[..., slot][..., None, None]

    # --- expert computation (all-to-all over the expert axis under EP)
    xe = jnp.einsum("bsec,bsd->becd", dispatch.astype(x.dtype), x)
    hi = jnp.einsum("becd,edf->becf", xe, p["wi"],
                    preferred_element_type=jnp.float32)
    if mlp_variant in ("swiglu", "geglu"):
        hg = jnp.einsum("becd,edf->becf", xe, p["wg"],
                        preferred_element_type=jnp.float32)
        act = (jax.nn.silu(hg) if mlp_variant == "swiglu"
               else nn.gelu(hg)) * hi
    else:
        act = nn.gelu(hi)
    ye = jnp.einsum("becf,efd->becd", act.astype(x.dtype), p["wo"],
                    preferred_element_type=jnp.float32)
    out = jnp.einsum("bsec,becd->bsd", combine, ye).astype(x.dtype)

    # --- aux losses
    # switch load-balance: E * sum_e (fraction tokens to e) * (mean prob e)
    top1 = gate_idx[..., 0].reshape(-1)
    frac = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), 0)
    lb = E * jnp.sum(frac * probs.reshape(-1, E).mean(0))
    z = jnp.mean(jax.nn.logsumexp(logits, -1) ** 2)
    aux = {"load_balance": lb, "router_z": z,
           "expert_counts": counts.sum(0).astype(jnp.float32)}
    return out, aux
