"""Conditional GAN exactly per paper Table 3, built as a *layered* model.

Generator  (z in R^100, label in R^10 -> 28x28 image):
  L0: label embed + concat, FC -> 256*7*7, BN, ReLU
  L1: ConvT 256->128 4x4 s2, BN, ReLU          (7 -> 14)
  L2: ConvT 128->128 3x3 s1, BN, ReLU          (14 -> 14)   <- middle
  L3: ConvT 128->64  4x4 s2, BN, ReLU          (14 -> 28)
  L4: ConvT 64->1    3x3 s1, Tanh              (28 -> 28)

Discriminator (image 28x28 + label channel -> prob):
  L0: label embed -> 28x28 channel, concat; Conv 2->64   4x4 s2, BN, LReLU (28->14)
  L1: Conv 64->128  4x4 s2, BN, LReLU                    (14->7)
  L2: Conv 128->128 3x3 s1, BN, LReLU                    (7->7)  <- middle
  L3: Conv 128->256 4x4 s2, BN, LReLU                    (7->4)
  L4: Flatten, FC->1 (logit; sigmoid applied in loss)

Each layer is an (init, apply) pair; `apply(params, x, train)` returns
(y, new_params) because of BatchNorm state. The HuSCF splitter treats
the model as the ordered list of these 5 layers.

FLOP/activation-byte accounting per layer (used by the latency model,
paper Eq. 3-6) is provided by `gan_layer_costs`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models import nn

Z_DIM = 100
NUM_CLASSES = 10
IMG = 28

GEN_LAYERS = 5
DISC_LAYERS = 5
GEN_MIDDLE = GEN_LAYERS // 2   # layer index that must live on the server
DISC_MIDDLE = DISC_LAYERS // 2
# flattened per-sample D middle activation (L2 output 7x7x128) — the
# feature width of the clustering EMA carried through fused epochs
DISC_MIDDLE_FEATURES = 7 * 7 * 128


# ---------------------------------------------------------------------------
# Generator layers
# ---------------------------------------------------------------------------

def _g0_init(key, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": nn.embedding_init(k1, NUM_CLASSES, NUM_CLASSES, dtype=dtype),
        "fc": nn.dense_init(k2, Z_DIM + NUM_CLASSES, 256 * 7 * 7, dtype=dtype),
        "bn": nn.batchnorm_init(256, dtype),
    }


def _g0_apply(p, x, train):
    z, y = x  # z [B, Z], y [B] int
    e = nn.embedding_apply(p["embed"], y)
    h = jnp.concatenate([z, e.astype(z.dtype)], -1)
    h = nn.dense_apply(p["fc"], h)
    h = h.reshape(h.shape[0], 7, 7, 256)
    h, bn = nn.batchnorm_apply(p["bn"], h, train=train)
    return jax.nn.relu(h), {**p, "bn": bn}


def _gconvt_init(cin, cout, k):
    def init(key, dtype):
        return {"convt": nn.convT2d_init(key, cin, cout, k, dtype=dtype),
                "bn": nn.batchnorm_init(cout, dtype)}
    return init


def _gconvt_apply(stride, final=False):
    def apply(p, x, train):
        h = nn.convT2d_apply(p["convt"], x, stride=stride)
        if final:
            return jnp.tanh(h), p
        h, bn = nn.batchnorm_apply(p["bn"], h, train=train)
        return jax.nn.relu(h), {**p, "bn": bn}
    return apply


def _g4_init(key, dtype):
    return {"convt": nn.convT2d_init(key, 64, 1, 3, dtype=dtype)}


GEN_LAYER_DEFS: List[Tuple[Callable, Callable]] = [
    (_g0_init, _g0_apply),
    (_gconvt_init(256, 128, 4), _gconvt_apply(2)),
    (_gconvt_init(128, 128, 3), _gconvt_apply(1)),
    (_gconvt_init(128, 64, 4), _gconvt_apply(2)),
    (_g4_init, _gconvt_apply(1, final=True)),
]


# ---------------------------------------------------------------------------
# Discriminator layers
# ---------------------------------------------------------------------------

def _d0_init(key, dtype):
    k1, k2 = jax.random.split(key)
    return {"embed": nn.embedding_init(k1, NUM_CLASSES, IMG * IMG, dtype=dtype),
            "conv": nn.conv2d_init(k2, 2, 64, 4, dtype=dtype),
            "bn": nn.batchnorm_init(64, dtype)}


def _d0_apply(p, x, train):
    img, y = x  # img [B,28,28,1], y [B]
    e = nn.embedding_apply(p["embed"], y).reshape(-1, IMG, IMG, 1)
    h = jnp.concatenate([img, e.astype(img.dtype)], -1)
    h = nn.conv2d_apply(p["conv"], h, stride=2)
    h, bn = nn.batchnorm_apply(p["bn"], h, train=train)
    return nn.leaky_relu(h), {**p, "bn": bn}


def _dconv_init(cin, cout, k):
    def init(key, dtype):
        return {"conv": nn.conv2d_init(key, cin, cout, k, dtype=dtype),
                "bn": nn.batchnorm_init(cout, dtype)}
    return init


def _dconv_apply(stride):
    def apply(p, x, train):
        h = nn.conv2d_apply(p["conv"], x, stride=stride)
        h, bn = nn.batchnorm_apply(p["bn"], h, train=train)
        return nn.leaky_relu(h), {**p, "bn": bn}
    return apply


def _d4_init(key, dtype):
    return {"fc": nn.dense_init(key, 4 * 4 * 256, 1, dtype=dtype)}


def _d4_apply(p, x, train):
    h = x.reshape(x.shape[0], -1)
    return nn.dense_apply(p["fc"], h)[:, 0], p  # logits


DISC_LAYER_DEFS: List[Tuple[Callable, Callable]] = [
    (_d0_init, _d0_apply),
    (_dconv_init(64, 128, 4), _dconv_apply(2)),
    (_dconv_init(128, 128, 3), _dconv_apply(1)),
    (_dconv_init(128, 256, 4), _dconv_apply(2)),
    (_d4_init, _d4_apply),
]


def init_generator(key, dtype=jnp.float32) -> List[Dict]:
    keys = jax.random.split(key, GEN_LAYERS)
    return [d[0](k, dtype) for d, k in zip(GEN_LAYER_DEFS, keys)]


def init_discriminator(key, dtype=jnp.float32) -> List[Dict]:
    keys = jax.random.split(key, DISC_LAYERS)
    return [d[0](k, dtype) for d, k in zip(DISC_LAYER_DEFS, keys)]


def run_layers(defs, params: List[Dict], x, *, start: int, stop: int,
               train: bool):
    """Run layers [start, stop); returns (activations, new_params_list)."""
    new_params = list(params)
    for i in range(start, stop):
        x, new_params[i] = defs[i][1](params[i], x, train)
    return x, new_params


def generator_forward(params, z, y, *, train: bool):
    return run_layers(GEN_LAYER_DEFS, params, (z, y), start=0,
                      stop=GEN_LAYERS, train=train)


def discriminator_forward(params, img, y, *, train: bool):
    return run_layers(DISC_LAYER_DEFS, params, (img, y), start=0,
                      stop=DISC_LAYERS, train=train)


# ---------------------------------------------------------------------------
# per-layer cost model (FLOPs forward, activation bytes out) for latency Eq 3-6
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerCost:
    flops_fwd: float      # per-sample forward FLOPs
    act_bytes: float      # per-sample activation bytes at layer OUTPUT
    params: int

    @property
    def flops_bwd(self) -> float:
        return 2.0 * self.flops_fwd  # standard backward ~ 2x forward


def _conv_cost(h, w, cin, cout, k):
    return 2.0 * h * w * cin * cout * k * k


GEN_LAYER_COSTS: List[LayerCost] = [
    LayerCost(2.0 * (Z_DIM + NUM_CLASSES) * 256 * 49, 7 * 7 * 256 * 4, (Z_DIM + NUM_CLASSES) * 256 * 49 + 256 * 49 + 100),
    LayerCost(_conv_cost(14, 14, 256, 128, 4), 14 * 14 * 128 * 4, 256 * 128 * 16 + 128),
    LayerCost(_conv_cost(14, 14, 128, 128, 3), 14 * 14 * 128 * 4, 128 * 128 * 9 + 128),
    LayerCost(_conv_cost(28, 28, 128, 64, 4), 28 * 28 * 64 * 4, 128 * 64 * 16 + 64),
    LayerCost(_conv_cost(28, 28, 64, 1, 3), 28 * 28 * 1 * 4, 64 * 9 + 1),
]

DISC_LAYER_COSTS: List[LayerCost] = [
    LayerCost(_conv_cost(14, 14, 2, 64, 4), 14 * 14 * 64 * 4, 2 * 64 * 16 + 64 + 10 * 784),
    LayerCost(_conv_cost(7, 7, 64, 128, 4), 7 * 7 * 128 * 4, 64 * 128 * 16 + 128),
    LayerCost(_conv_cost(7, 7, 128, 128, 3), 7 * 7 * 128 * 4, 128 * 128 * 9 + 128),
    LayerCost(_conv_cost(4, 4, 128, 256, 4), 4 * 4 * 256 * 4, 128 * 256 * 16 + 256),
    LayerCost(2.0 * 4 * 4 * 256 * 1, 1 * 4, 4 * 4 * 256 + 1),
]


def gan_layer_costs():
    return GEN_LAYER_COSTS, DISC_LAYER_COSTS


# ---------------------------------------------------------------------------
# GAN losses (non-saturating BCE on logits, as in the paper's cGAN)
# ---------------------------------------------------------------------------

def bce_logits(logits, target: float):
    t = jnp.full_like(logits, target)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * t + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def d_loss_fn(d_logits_real, d_logits_fake):
    return bce_logits(d_logits_real, 1.0) + bce_logits(d_logits_fake, 0.0)


def g_loss_fn(d_logits_fake):
    return bce_logits(d_logits_fake, 1.0)
