"""Small CNN classifier used for evaluation (paper §5 metric 1 & 2).

Trained (a) on real data to act as the dataset-specific scoring network
(IS-style score + FID features), and (b) on generated samples to compute
the classification metrics vs a real test set.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import nn
from repro.optim import adam


def init_cnn(key, num_classes: int = 10, dtype=jnp.float32) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "c1": nn.conv2d_init(k1, 1, 32, 3, dtype=dtype),
        "c2": nn.conv2d_init(k2, 32, 64, 3, dtype=dtype),
        "fc1": nn.dense_init(k3, 7 * 7 * 64, 128, dtype=dtype),
        "fc2": nn.dense_init(k4, 128, num_classes, dtype=dtype),
    }


def cnn_apply(params: Dict, x: jnp.ndarray,
              return_features: bool = False):
    h = nn.conv2d_apply(params["c1"], x, stride=2)
    h = jax.nn.relu(h)
    h = nn.conv2d_apply(params["c2"], h, stride=2)
    h = jax.nn.relu(h)
    h = h.reshape(h.shape[0], -1)
    feat = jax.nn.relu(nn.dense_apply(params["fc1"], h))
    logits = nn.dense_apply(params["fc2"], feat)
    if return_features:
        return logits, feat
    return logits


def xent(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def train_classifier(key, images: np.ndarray, labels: np.ndarray, *,
                     epochs: int = 3, batch: int = 128, lr: float = 1e-3,
                     num_classes: int = 10) -> Dict:
    """Train the CNN; returns params. images in [-1,1] [N,H,W,1]."""
    params = init_cnn(key, num_classes)
    opt_init, opt_update = adam(lr)
    opt_state = opt_init(params)

    @jax.jit
    def step(params, opt_state, xb, yb):
        loss, grads = jax.value_and_grad(
            lambda p: xent(cnn_apply(p, xb), yb))(params)
        opt_state, params = opt_update(opt_state, grads, params)
        return params, opt_state, loss

    rng = np.random.default_rng(0)
    n = images.shape[0]
    for _ in range(epochs):
        order = rng.permutation(n)
        for b in range(max(1, n // batch)):
            sel = order[b * batch:(b + 1) * batch]
            if sel.size == 0:
                continue
            params, opt_state, _ = step(params, opt_state,
                                        jnp.asarray(images[sel]),
                                        jnp.asarray(labels[sel]))
    return params


def predict(params: Dict, images: np.ndarray, batch: int = 512) -> np.ndarray:
    outs = []
    apply = jax.jit(lambda p, x: jnp.argmax(cnn_apply(p, x), -1))
    for b in range(0, images.shape[0], batch):
        outs.append(np.asarray(apply(params, jnp.asarray(images[b:b + batch]))))
    return np.concatenate(outs)


def predict_proba(params: Dict, images: np.ndarray, batch: int = 512) -> np.ndarray:
    outs = []
    apply = jax.jit(lambda p, x: jax.nn.softmax(cnn_apply(p, x), -1))
    for b in range(0, images.shape[0], batch):
        outs.append(np.asarray(apply(params, jnp.asarray(images[b:b + batch]))))
    return np.concatenate(outs)


def features(params: Dict, images: np.ndarray, batch: int = 512) -> np.ndarray:
    outs = []
    apply = jax.jit(lambda p, x: cnn_apply(p, x, return_features=True)[1])
    for b in range(0, images.shape[0], batch):
        outs.append(np.asarray(apply(params, jnp.asarray(images[b:b + batch]))))
    return np.concatenate(outs)
